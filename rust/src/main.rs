//! `vault` — CLI entry point for the VAULT reproduction.
//!
//! Subcommands:
//! * `cluster`      — run a virtual-time cluster, store + query objects.
//! * `bench-ops`    — open-loop mixed 70/30 get/store throughput bench
//!                    over the `VaultApi` surface; emits `BENCH_ops.json`.
//! * `bench-codec`  — coding/hashing data-plane kernel bench with
//!                    before/after reference rows and allocation counts;
//!                    emits `BENCH_codec.json`.
//! * `bench-maint`  — maintenance-plane bandwidth + repair-convergence
//!                    bench, legacy vs batched heartbeats in the same
//!                    process; emits `BENCH_maint.json`.
//! * `bench-epoch`  — epoch-chain footprint bench: on-chain bytes/epoch
//!                    vs object count and vs cluster size (should be
//!                    churn-bound, object-independent), migration
//!                    traffic per rotation, availability during
//!                    reconfiguration; emits `BENCH_epoch.json`.
//! * `bench-restart`— crash-restart recovery bench (ISSUE 6): WAL
//!                    replay cost vs stored chunks, clean and torn-tail
//!                    restart waves with durability-loss and
//!                    re-convergence accounting; emits
//!                    `BENCH_restart.json`.
//! * `bench-audit`  — retrievability audit plane bench (ISSUE 7):
//!                    withholder detection latency vs sampling rate,
//!                    audit bytes/node/epoch, and the zero-false-
//!                    positive count; emits `BENCH_audit.json`.
//! * `bench-adversary` — adversarial resilience bench (ISSUE 8): the
//!                    five fault families (eclipse, beacon
//!                    equivocation, censorship, slow-loris, adaptive
//!                    withholding) each run as a defenses-off /
//!                    defenses-on twin, reporting the detection signal,
//!                    the availability floor, the detection window, and
//!                    the zero-false-greylist count; emits
//!                    `BENCH_adversary.json`.
//! * `bench-scale`  — scale-runtime bench (ISSUE 9): idle-heavy
//!                    clusters up to 100k peers on the timer-wheel
//!                    runtime with interned peer state and cold-group
//!                    aggregation; reports wall-s per virtual-s,
//!                    resident bytes/peer, and events/s; emits
//!                    `BENCH_scale.json`.
//! * `bench-read`   — heavy-traffic read-path bench (ISSUE 10): zipf
//!                    open-loop get storms against a cluster whose
//!                    nearer replicas reply slow-loris, naive fan-out
//!                    vs ranked + hedged + cached + coalesced, with
//!                    tail latencies (p50/p99/p999), goodput per
//!                    network byte, and the hedge/cache/coalesce
//!                    rates; emits `BENCH_read.json`.
//! * `tcp-demo`     — bring up a real-TCP localhost cluster and do one
//!                    store/query round trip.
//! * `sim`          — §6.1 durability simulations (fig4|fig5|fig6).
//! * `analyze`      — Appendix-A CTMC + closed-form bounds.
//! * `artifacts`    — load the AOT artifacts and cross-check them
//!                    against the native codec.

use vault::analysis::{bounds, ctmc};
use vault::api::VaultApi;
use vault::coordinator::workload::{
    run_open_loop, run_read_storm, Corpus, OpenLoopReport, OpenLoopSpec, ReadStormSpec,
};
use vault::coordinator::{Cluster, ClusterConfig, ClusterRuntime};
use vault::crypto::Hash256;
use vault::runtime::Runtime;
use vault::sim::{attack, durability, replica};
use vault::util::cli::Args;
use vault::util::rng::Rng;
use vault::util::Timer;

/// Counting-allocator shim (util::alloc) so `bench-codec` can report the
/// decoders' steady-state allocation counts. Pass-through to the system
/// allocator plus one thread-local counter bump per allocation —
/// negligible for every other subcommand.
#[global_allocator]
static ALLOC: vault::util::alloc::CountingAlloc = vault::util::alloc::CountingAlloc;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "cluster" => cmd_cluster(&args),
        "bench-ops" => cmd_bench_ops(&args),
        "bench-codec" => cmd_bench_codec(&args),
        "bench-maint" => cmd_bench_maint(&args),
        "bench-epoch" => cmd_bench_epoch(&args),
        "bench-restart" => cmd_bench_restart(&args),
        "bench-audit" => cmd_bench_audit(&args),
        "bench-adversary" => cmd_bench_adversary(&args),
        "bench-scale" => cmd_bench_scale(&args),
        "bench-read" => cmd_bench_read(&args),
        "tcp-demo" => cmd_tcp_demo(&args),
        "sim" => cmd_sim(&args),
        "analyze" => cmd_analyze(&args),
        "artifacts" => cmd_artifacts(&args),
        _ => {
            eprintln!(
                "usage: vault <cluster|bench-ops|bench-codec|bench-maint|bench-epoch|bench-restart|bench-audit|bench-adversary|bench-scale|bench-read|tcp-demo|sim|analyze|artifacts> [--flags]\n\
                 \n\
                 cluster     --peers 128 --objects 4 --size 262144 [--byzantine 0.1] [--churn 4]\n\
                 bench-ops   --peers 64 --ops 300 --inflight 32 --size 32768 [--sharded 0]\n\
                 \x20            [--seed 7] [--out BENCH_ops.json]\n\
                 bench-codec [--smoke] [--seed 7] [--out BENCH_codec.json]\n\
                 bench-maint [--smoke] [--peers 256] [--chunks 64] [--r 16] [--minutes 5]\n\
                 \x20            [--seed 7] [--out BENCH_maint.json]\n\
                 bench-epoch [--smoke] [--epochs 4] [--epoch-ms 60000] [--churn 4]\n\
                 \x20            [--seed 7] [--out BENCH_epoch.json]\n\
                 bench-restart [--smoke] [--peers 64] [--r 16] [--seed 7]\n\
                 \x20            [--out BENCH_restart.json]\n\
                 bench-audit [--smoke] [--peers 48] [--withhold 4] [--epochs 8]\n\
                 \x20            [--seed 7] [--out BENCH_audit.json]\n\
                 bench-adversary [--smoke] [--seed 7] [--out BENCH_adversary.json]\n\
                 bench-scale [--smoke] [--virtual-s 60] [--seed 7] [--out BENCH_scale.json]\n\
                 bench-read  [--smoke] [--gets 12000] [--inflight 10000] [--peers 96]\n\
                 \x20            [--seed 7] [--out BENCH_read.json]\n\
                 tcp-demo    --peers 8 --size 65536\n\
                 sim         --fig 4|5|6 [--nodes 100000] [--objects 1000] [--churn 2.0] [--years 1]\n\
                 analyze     [--n 80] [--k 32] [--churn-q 0.01] [--evict 0] [--steps 512]\n\
                 artifacts   [--dir artifacts]"
            );
        }
    }
}

/// Seed the corpus through blocking stores, then run the open-loop
/// workload — shared by the serial and sharded bench paths.
fn seed_and_run<N: ClusterRuntime>(
    mut cluster: Cluster<N>,
    seed_corpus: &Corpus,
    spec: &OpenLoopSpec,
) -> (OpenLoopReport, u64) {
    let mut refs = Vec::new();
    for (data, secret) in &seed_corpus.objects {
        let client = cluster.random_client();
        refs.push(cluster.store_blocking(client, data, secret, 0).expect("seed store").value);
    }
    let report = run_open_loop(&mut cluster, spec, &mut refs);
    let now = cluster.net.now_ms();
    (report, now)
}

/// Open-loop mixed 70/30 get/store throughput benchmark through the
/// `VaultApi` submission/completion surface. Emits a JSON record so the
/// perf trajectory is machine-diffable across PRs.
fn cmd_bench_ops(args: &Args) {
    let peers = args.get("peers", 64usize);
    let ops = args.get("ops", 300usize);
    let inflight = args.get("inflight", 32usize);
    let size = args.get("size", 32 * 1024usize);
    let seed = args.get("seed", 7u64);
    let shards = args.get("sharded", 0usize);
    let out = args.str("out", "BENCH_ops.json");

    let mut cfg = ClusterConfig::small_test(peers);
    cfg.seed = seed;
    println!(
        "bench-ops: {peers} peers{} | {ops} ops, {inflight} in flight, {size} B objects",
        if shards > 0 { format!(" / {shards} shards") } else { String::new() }
    );
    let spec = OpenLoopSpec {
        seed,
        total_ops: ops,
        target_in_flight: inflight,
        store_frac: 0.3, // 70/30 get/store
        mean_interarrival_ms: 50.0,
        object_size: size,
        deadline_ms: None,
        max_virtual_ms: 3_600_000,
    };
    let wall = Timer::start();
    // Seed a few objects so the get side has targets from the start.
    let seed_corpus = Corpus::generate(seed ^ 0xBE9C, 4, size);
    let (report, virtual_ms) = if shards > 0 {
        seed_and_run(Cluster::start_sharded(cfg, shards), &seed_corpus, &spec)
    } else {
        seed_and_run(Cluster::start(cfg), &seed_corpus, &spec)
    };
    let wall_s = wall.elapsed_s();
    let completed = report.ok + report.failed;
    let (p50, p99) = report.latency_percentiles();
    let (store_p50, store_p99) =
        (report.store_latency.percentile(50.0), report.store_latency.percentile(99.0));
    let (get_p50, get_p99) =
        (report.get_latency.percentile(50.0), report.get_latency.percentile(99.0));
    let json = format!(
        "{{\n  \"bench\": \"open_loop_mixed_70_30\",\n  \"peers\": {peers},\n  \
         \"shards\": {shards},\n  \"seed\": {seed},\n  \"object_bytes\": {size},\n  \
         \"ops_submitted\": {},\n  \"ops_ok\": {},\n  \"ops_failed\": {},\n  \
         \"target_in_flight\": {inflight},\n  \"elapsed_virtual_ms\": {},\n  \
         \"ops_per_virtual_sec\": {:.3},\n  \"wall_secs\": {wall_s:.3},\n  \
         \"ops_per_wall_sec\": {:.3},\n  \"latency_p50_ms\": {p50:.1},\n  \
         \"latency_p99_ms\": {p99:.1},\n  \"store_p50_ms\": {store_p50:.1},\n  \
         \"store_p99_ms\": {store_p99:.1},\n  \"get_p50_ms\": {get_p50:.1},\n  \
         \"get_p99_ms\": {get_p99:.1},\n  \"bytes_stored\": {},\n  \
         \"bytes_fetched\": {},\n  \"fingerprint\": {}\n}}\n",
        report.submitted,
        report.ok,
        report.failed,
        report.elapsed_virtual_ms,
        report.ops_per_vsec(),
        completed as f64 / wall_s.max(1e-9),
        report.bytes_stored,
        report.bytes_fetched,
        report.fingerprint,
    );
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
    println!(
        "completed {completed}/{} ops in {:.1} virtual s ({:.1} wall s): \
         {:.1} ops/vs, p50 {p50:.0} ms, p99 {p99:.0} ms",
        report.submitted,
        report.elapsed_virtual_ms as f64 / 1e3,
        wall_s,
        report.ops_per_vsec(),
    );
    println!("virtual clock ended at {} s", virtual_ms / 1000);
}

/// Coding/hashing data-plane kernel benchmark (ISSUE 3): MB/s for the
/// xor / GF(256) / inner / outer / sha256 kernels, before/after rows via
/// the kept `codec::reference` implementations measured in the same run,
/// and steady-state allocation counts from the counting-allocator shim.
/// Emits `BENCH_codec.json` so the codec perf trajectory is
/// machine-diffable across PRs.
fn cmd_bench_codec(args: &Args) {
    use vault::codec::rateless::{coeff_row, InnerDecoder, InnerEncoder};
    use vault::codec::reference::{
        addmul_slice_ref, coeff_row_bools, scale_slice_ref, InnerDecoderRef, OuterDecoderRef,
    };
    use vault::codec::xor::xor_into;
    use vault::codec::{gf256, outer, OuterDecoder};
    use vault::util::alloc;

    let smoke = args.bool("smoke");
    let seed = args.get("seed", 7u64);
    let out = args.str("out", "BENCH_codec.json");
    // Smoke mode: tiny buffers + single iterations so CI can prove the
    // bench never rots without paying for a real measurement.
    let slice_len: usize = if smoke { 64 << 10 } else { 1 << 20 };
    let chunk_len: usize = if smoke { 64 << 10 } else { 512 << 10 };
    let object_len: usize = if smoke { 256 << 10 } else { 4 << 20 };
    let iters = |n: usize| if smoke { 1 } else { n };
    let (k_inner, k_outer, n_outer) = (32usize, 8usize, 10usize);
    println!(
        "bench-codec{}: slice {slice_len} B, chunk {chunk_len} B, object {object_len} B",
        if smoke { " (smoke)" } else { "" }
    );

    /// Median-free throughput probe: warm once, time `iters` runs.
    fn mbps<F: FnMut()>(name: &str, iters: usize, bytes: usize, mut f: F) -> f64 {
        f();
        let t = Timer::start();
        for _ in 0..iters {
            f();
        }
        let v = bytes as f64 * iters as f64 / t.elapsed_s() / 1e6;
        println!("  {name:<34} {v:>9.0} MB/s");
        v
    }

    let wall = Timer::start();
    let mut rng = Rng::new(seed);
    let mut a = vec![0u8; slice_len];
    let mut b = vec![0u8; slice_len];
    rng.fill_bytes(&mut a);
    rng.fill_bytes(&mut b);
    let xor_mbps = mbps("xor", iters(200), slice_len, || xor_into(&mut a, &b));
    let sha256_mbps = mbps("sha256", iters(50), slice_len, || {
        let _ = Hash256::of(&a);
    });
    let addmul_ref_mbps =
        mbps("addmul (ref per-byte)", iters(20), slice_len, || addmul_slice_ref(&mut a, &b, 0xA7));
    let addmul_mbps =
        mbps("addmul (table)", iters(50), slice_len, || gf256::addmul_slice(&mut a, &b, 0xA7));
    let scale_ref_mbps =
        mbps("scale (ref per-byte)", iters(20), slice_len, || scale_slice_ref(&mut a, 0xA7));
    let scale_mbps =
        mbps("scale (table)", iters(50), slice_len, || gf256::scale_slice(&mut a, 0xA7));

    // Inner code.
    let mut chunk = vec![0u8; chunk_len];
    rng.fill_bytes(&mut chunk);
    let chash = Hash256::of(&chunk);
    let enc = InnerEncoder::new(chash, &chunk, k_inner);
    let batch: Vec<u64> = (0..(k_inner as u64 * 5 / 2)).collect(); // R = 2.5k
    let batch_bytes = chunk_len * batch.len() / k_inner;
    let inner_encode_mbps = mbps("inner encode R=80", iters(5), batch_bytes, || {
        let _ = enc.fragments(&batch);
    });
    let mut arena = Vec::new();
    enc.fragments_into(&batch, &mut arena);
    let inner_encode_arena_mbps = mbps("inner encode R=80 (arena)", iters(5), batch_bytes, || {
        enc.fragments_into(&batch, &mut arena);
    });
    let frags: Vec<_> = (0..(k_inner as u64 + 8)).map(|i| enc.fragment(i)).collect();
    let inner_decode_ref_mbps = mbps("inner decode k=32 (ref bools)", iters(3), chunk_len, || {
        let mut dec = InnerDecoderRef::new(chash, k_inner);
        for f in &frags {
            if dec.is_complete() {
                break;
            }
            dec.push(f);
        }
        assert!(dec.is_complete());
    });
    let inner_decode_mbps = mbps("inner decode k=32 (packed)", iters(5), chunk_len, || {
        let mut dec = InnerDecoder::new(chash, k_inner);
        for f in &frags {
            if dec.is_complete() {
                break;
            }
            dec.push(f);
        }
        assert!(dec.is_complete());
    });
    let coeff_iters = iters(2000);
    let t = Timer::start();
    for i in 0..coeff_iters {
        let _ = coeff_row_bools(&chash, i as u64, k_inner);
    }
    let coeff_row_ref_per_s = coeff_iters as f64 / t.elapsed_s();
    let t = Timer::start();
    for i in 0..coeff_iters {
        let _ = coeff_row(&chash, i as u64, k_inner);
    }
    let coeff_row_per_s = coeff_iters as f64 / t.elapsed_s();

    // Outer code.
    let mut object = vec![0u8; object_len];
    rng.fill_bytes(&mut object);
    let outer_encode_mbps = mbps("outer encode (10,8)", iters(5), object_len, || {
        let _ = outer::encode_object(&object, b"bench", k_outer, n_outer);
    });
    let (_, chunks) = outer::encode_object(&object, b"bench", k_outer, n_outer);
    let outer_decode_ref_mbps = mbps("outer decode (ref clones)", iters(3), object_len, || {
        let mut dec = OuterDecoderRef::new(k_outer);
        for c in &chunks {
            if dec.is_complete() {
                break;
            }
            dec.push(&c.bytes);
        }
        assert!(dec.is_complete());
    });
    let outer_decode_mbps = mbps("outer decode (arena)", iters(5), object_len, || {
        let mut dec = OuterDecoder::new(k_outer);
        for c in &chunks {
            if dec.is_complete() {
                break;
            }
            dec.push(&c.bytes);
        }
        assert!(dec.is_complete());
    });

    // Steady-state allocation counts (first push sizes the arena and is
    // excluded by design — see DESIGN.md §Perf).
    let alloc_counter_active = alloc::counts_allocations();
    let mut dec = InnerDecoder::new(chash, k_inner);
    dec.push(&frags[0]);
    let (inner_push_steady_allocs, _, ()) = alloc::count(|| {
        for f in &frags[1..] {
            dec.push(f);
        }
    });
    let mut dec = OuterDecoder::new(k_outer);
    dec.push(&chunks[0].bytes);
    let (outer_push_steady_allocs, _, ()) = alloc::count(|| {
        for c in &chunks[1..] {
            dec.push(&c.bytes);
        }
    });
    println!(
        "  steady-state allocs: inner push {inner_push_steady_allocs}, \
         outer push {outer_push_steady_allocs} (counter active: {alloc_counter_active})"
    );

    let wall_secs = wall.elapsed_s();
    let addmul_speedup = addmul_mbps / addmul_ref_mbps.max(1e-9);
    let inner_decode_speedup = inner_decode_mbps / inner_decode_ref_mbps.max(1e-9);
    let outer_decode_speedup = outer_decode_mbps / outer_decode_ref_mbps.max(1e-9);
    let json = format!(
        "{{\n  \"bench\": \"codec_data_plane\",\n  \"smoke\": {smoke},\n  \"seed\": {seed},\n  \
         \"slice_bytes\": {slice_len},\n  \"chunk_bytes\": {chunk_len},\n  \
         \"object_bytes\": {object_len},\n  \"k_inner\": {k_inner},\n  \
         \"k_outer\": {k_outer},\n  \"n_outer\": {n_outer},\n  \
         \"xor_mbps\": {xor_mbps:.1},\n  \"sha256_mbps\": {sha256_mbps:.1},\n  \
         \"addmul_ref_mbps\": {addmul_ref_mbps:.1},\n  \"addmul_mbps\": {addmul_mbps:.1},\n  \
         \"scale_ref_mbps\": {scale_ref_mbps:.1},\n  \"scale_mbps\": {scale_mbps:.1},\n  \
         \"inner_encode_mbps\": {inner_encode_mbps:.1},\n  \
         \"inner_encode_arena_mbps\": {inner_encode_arena_mbps:.1},\n  \
         \"inner_decode_ref_mbps\": {inner_decode_ref_mbps:.1},\n  \
         \"inner_decode_mbps\": {inner_decode_mbps:.1},\n  \
         \"coeff_row_ref_per_s\": {coeff_row_ref_per_s:.0},\n  \
         \"coeff_row_per_s\": {coeff_row_per_s:.0},\n  \
         \"outer_encode_mbps\": {outer_encode_mbps:.1},\n  \
         \"outer_decode_ref_mbps\": {outer_decode_ref_mbps:.1},\n  \
         \"outer_decode_mbps\": {outer_decode_mbps:.1},\n  \
         \"addmul_speedup\": {addmul_speedup:.2},\n  \
         \"inner_decode_speedup\": {inner_decode_speedup:.2},\n  \
         \"outer_decode_speedup\": {outer_decode_speedup:.2},\n  \
         \"inner_push_steady_allocs\": {inner_push_steady_allocs},\n  \
         \"outer_push_steady_allocs\": {outer_push_steady_allocs},\n  \
         \"alloc_counter_active\": {alloc_counter_active},\n  \"wall_secs\": {wall_secs:.3}\n}}\n",
    );
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
    println!(
        "speedups: addmul {addmul_speedup:.2}x, inner decode {inner_decode_speedup:.2}x, \
         outer decode {outer_decode_speedup:.2}x ({wall_secs:.1}s wall)"
    );
}

/// One maintenance-plane trial: a pre-seeded SimNet cluster running
/// heartbeats for a measurement window (steady-state bandwidth), then a
/// crash burst driven to repair convergence.
struct MaintTrial {
    hb_bytes_per_node_min: f64,
    hb_msgs_per_node_min: f64,
    repair_bytes: u64,
    converge_ms: u64,
    converged: bool,
}

fn run_maint_trial(
    peers: usize,
    chunks_per_node: usize,
    r: usize,
    seed: u64,
    minutes: u64,
    batched: bool,
) -> MaintTrial {
    use vault::codec::rateless::InnerEncoder;
    use vault::crypto::vrf;
    use vault::dht::PeerInfo;
    use vault::net::simnet::{SimNet, SimOpts};
    use vault::proto::{ClaimVerify, VaultConfig};

    let k_inner = 4usize.min(r);
    let cfg = VaultConfig {
        k_inner,
        r_inner: r,
        k_outer: 2,
        n_outer: 3,
        n_nodes: peers,
        candidates: (3 * r).min(peers),
        // VRF verification is the documented large-cluster measurement
        // knob (proto::ClaimVerify); this bench measures bandwidth and
        // convergence, not crypto throughput.
        claim_verify: ClaimVerify::Never,
        batched_maint: batched,
        heartbeat_ms: 10_000,
        suspicion_ms: 30_000,
        tick_ms: 10_000,
        ..Default::default()
    };
    let opts = SimOpts { seed, ..Default::default() };
    let mut net = SimNet::new(cfg, peers, opts);

    // Pre-seed `peers · chunks_per_node / r` chunk groups with real
    // (hash-verifiable) chunk content so repair joins can reconstruct.
    let n_groups = (peers * chunks_per_node / r).max(1);
    let mut rng = Rng::new(seed ^ 0x4A17);
    let mut chashes = Vec::with_capacity(n_groups);
    for _ in 0..n_groups {
        let mut chunk = vec![0u8; 256];
        rng.fill_bytes(&mut chunk);
        let chash = Hash256::of(&chunk);
        chashes.push(chash);
        let member_idx = rng.sample_indices(peers, r);
        let infos: Vec<PeerInfo> = member_idx.iter().map(|&i| net.peer(i).info).collect();
        let enc = InnerEncoder::new(chash, &chunk, k_inner);
        for (slot, &i) in member_idx.iter().enumerate() {
            let frag = enc.fragment(slot as u64);
            let proof = vrf::prove(&net.peer(i).key, b"bench-maint").1;
            let others: Vec<PeerInfo> =
                infos.iter().filter(|p| p.id != net.peer(i).info.id).copied().collect();
            net.peer_mut(i).force_store(0, chash, frag, proof, others);
        }
    }

    // Warm up past every node's first (jittered) tick so the batched
    // plane's one-time full-list announcements sit outside the window.
    net.run_for(25_000);
    let before = net.maint_stats();
    let t0 = net.now_ms();
    net.run_for(minutes.max(1) * 60_000);
    let after = net.maint_stats();
    let span_min = (net.now_ms() - t0) as f64 / 60_000.0;
    let hb_bytes = after.hb_bytes - before.hb_bytes;
    let hb_msgs = after.hb_msgs - before.hb_msgs;

    // Crash burst, then drive to repair convergence.
    let kill_n = (peers / 16).max(1);
    let mut killed = 0usize;
    for i in 0..peers {
        if killed >= kill_n {
            break;
        }
        if net.is_up(i) {
            net.kill(i);
            killed += 1;
        }
    }
    let repair_before = net.maint_stats();
    let repair_payload_before = net.total_repair_traffic();
    let start = net.now_ms();
    let deadline = start + 40 * 60_000;
    let mut converged = false;
    while net.now_ms() < deadline {
        net.run_for(10_000);
        if chashes.iter().all(|c| net.surviving_fragments(c) >= r) {
            converged = true;
            break;
        }
    }
    let converge_ms = net.now_ms() - start;
    let repair_after = net.maint_stats();

    MaintTrial {
        hb_bytes_per_node_min: hb_bytes as f64 / peers as f64 / span_min.max(1e-9),
        hb_msgs_per_node_min: hb_msgs as f64 / peers as f64 / span_min.max(1e-9),
        repair_bytes: (repair_after.repair_bytes - repair_before.repair_bytes)
            + (net.total_repair_traffic() - repair_payload_before),
        converge_ms,
        converged,
    }
}

/// Maintenance-plane bandwidth + repair-convergence benchmark (ISSUE
/// 4): the legacy per-chunk heartbeat plane and the batched per-peer
/// plane run in the same process on identically seeded clusters, and
/// the JSON row pair makes the bytes/node/min reduction machine-
/// diffable across PRs.
fn cmd_bench_maint(args: &Args) {
    let smoke = args.bool("smoke");
    let peers = args.get("peers", if smoke { 32 } else { 256usize });
    let chunks_per_node = args.get("chunks", if smoke { 8 } else { 64usize });
    let r = args.get("r", 16usize);
    let seed = args.get("seed", 7u64);
    let minutes = args.get("minutes", if smoke { 2 } else { 5u64 });
    let out = args.str("out", "BENCH_maint.json");
    let groups = (peers * chunks_per_node / r).max(1);
    println!(
        "bench-maint{}: {peers} peers, {chunks_per_node} chunks/node, R={r} \
         ({groups} groups), {minutes} min window",
        if smoke { " (smoke)" } else { "" }
    );

    let wall = Timer::start();
    let legacy = run_maint_trial(peers, chunks_per_node, r, seed, minutes, false);
    println!(
        "  legacy : {:>12.0} hb B/node/min, {:>8.1} hb msgs/node/min, converge {} ms{}",
        legacy.hb_bytes_per_node_min,
        legacy.hb_msgs_per_node_min,
        legacy.converge_ms,
        if legacy.converged { "" } else { " (NOT converged)" }
    );
    let batched = run_maint_trial(peers, chunks_per_node, r, seed, minutes, true);
    println!(
        "  batched: {:>12.0} hb B/node/min, {:>8.1} hb msgs/node/min, converge {} ms{}",
        batched.hb_bytes_per_node_min,
        batched.hb_msgs_per_node_min,
        batched.converge_ms,
        if batched.converged { "" } else { " (NOT converged)" }
    );
    let bytes_reduction = legacy.hb_bytes_per_node_min / batched.hb_bytes_per_node_min.max(1e-9);
    let msgs_reduction = legacy.hb_msgs_per_node_min / batched.hb_msgs_per_node_min.max(1e-9);
    let wall_secs = wall.elapsed_s();
    let json = format!(
        "{{\n  \"bench\": \"maintenance_plane\",\n  \"smoke\": {smoke},\n  \"seed\": {seed},\n  \
         \"peers\": {peers},\n  \"chunks_per_node\": {chunks_per_node},\n  \"r_inner\": {r},\n  \
         \"groups\": {groups},\n  \"measured_minutes\": {minutes},\n  \
         \"legacy_hb_bytes_per_node_min\": {:.1},\n  \
         \"legacy_hb_msgs_per_node_min\": {:.2},\n  \
         \"batched_hb_bytes_per_node_min\": {:.1},\n  \
         \"batched_hb_msgs_per_node_min\": {:.2},\n  \
         \"hb_bytes_reduction\": {bytes_reduction:.2},\n  \
         \"hb_msgs_reduction\": {msgs_reduction:.2},\n  \
         \"legacy_converge_ms\": {},\n  \"batched_converge_ms\": {},\n  \
         \"legacy_converged\": {},\n  \"batched_converged\": {},\n  \
         \"legacy_repair_bytes\": {},\n  \"batched_repair_bytes\": {},\n  \
         \"wall_secs\": {wall_secs:.3}\n}}\n",
        legacy.hb_bytes_per_node_min,
        legacy.hb_msgs_per_node_min,
        batched.hb_bytes_per_node_min,
        batched.hb_msgs_per_node_min,
        legacy.converge_ms,
        batched.converge_ms,
        legacy.converged,
        batched.converged,
        legacy.repair_bytes,
        batched.repair_bytes,
    );
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
    println!(
        "maintenance bytes/node/min reduced {bytes_reduction:.1}x, msgs {msgs_reduction:.1}x \
         ({wall_secs:.1}s wall)"
    );
}

/// Outcome of one epoch-chain trial (fixed peers/objects, several
/// sealed epochs with churn).
struct EpochTrial {
    peers: usize,
    objects: usize,
    /// Exact on-chain bytes appended by each measured epoch.
    onchain_bytes: Vec<u64>,
    /// Repair/migration payload pulled during each epoch window.
    migration_bytes: Vec<u64>,
    /// Reads issued right after each boundary (mid-reconfiguration).
    avail_ok: usize,
    avail_total: usize,
}

impl EpochTrial {
    fn mean_onchain(&self) -> f64 {
        self.onchain_bytes.iter().sum::<u64>() as f64 / self.onchain_bytes.len().max(1) as f64
    }
    fn mean_migration(&self) -> f64 {
        self.migration_bytes.iter().sum::<u64>() as f64
            / self.migration_bytes.len().max(1) as f64
    }
    fn availability(&self) -> f64 {
        self.avail_ok as f64 / self.avail_total.max(1) as f64
    }
    fn json_row(&self) -> String {
        let arr = |v: &[u64]| {
            let items: Vec<String> = v.iter().map(|x| x.to_string()).collect();
            format!("[{}]", items.join(", "))
        };
        format!(
            "{{\"peers\": {}, \"objects\": {}, \"onchain_bytes_per_epoch\": {}, \
             \"mean_onchain_bytes_per_epoch\": {:.1}, \"migration_bytes_per_epoch\": {}, \
             \"mean_migration_bytes_per_epoch\": {:.1}, \"availability_during_rotation\": {:.4}}}",
            self.peers,
            self.objects,
            arr(&self.onchain_bytes),
            self.mean_onchain(),
            arr(&self.migration_bytes),
            self.mean_migration(),
            self.availability(),
        )
    }
}

fn run_epoch_trial(
    peers: usize,
    objects: usize,
    epochs: u64,
    epoch_ms: u64,
    churn: usize,
    object_size: usize,
    seed: u64,
) -> EpochTrial {
    let mut cfg = ClusterConfig::small_test(peers);
    cfg.seed = seed;
    cfg.epoch_ms = epoch_ms;
    cfg.vault.rotation_grace_ms = epoch_ms / 3;
    // Fast maintenance timers so retirement detection and repair
    // convergence fit comfortably inside one epoch.
    cfg.vault.heartbeat_ms = 5_000;
    cfg.vault.suspicion_ms = 15_000;
    cfg.vault.tick_ms = 5_000;
    let mut cluster = Cluster::start(cfg);
    let mut rng = Rng::new(seed ^ 0xE90C);
    let mut ids = Vec::with_capacity(objects);
    for o in 0..objects {
        let mut data = vec![0u8; object_size];
        rng.fill_bytes(&mut data);
        let client = cluster.random_client();
        let id = cluster
            .store_blocking(client, &data, format!("epoch-bench-{o}").as_bytes(), 0)
            .expect("seed store")
            .value;
        ids.push((id, data));
    }

    let mut onchain_bytes = Vec::with_capacity(epochs as usize);
    let mut migration_bytes = Vec::with_capacity(epochs as usize);
    let (mut avail_ok, mut avail_total) = (0usize, 0usize);
    for _ in 0..epochs {
        let repair_before = cluster.net.total_repair_traffic();
        let epoch_before = cluster.ledger().expect("chain enabled").current_epoch();
        // This epoch's on-chain traffic: one churn wave of ledger txs.
        cluster.churn(churn);
        // Cross the boundary, then probe availability *during* the
        // reconfiguration window (groups mid-rotation).
        let boundary = ((cluster.net.now_ms() / epoch_ms) + 1) * epoch_ms;
        cluster.drive(boundary + 1_000);
        for (id, want) in ids.iter().take(4) {
            let client = cluster.random_client();
            avail_total += 1;
            let ok = cluster
                .query_blocking(client, id)
                .map(|r| &r.value == want)
                .unwrap_or(false);
            if ok {
                avail_ok += 1;
            }
        }
        // Let the rotation converge before the next boundary.
        let settle = boundary + epoch_ms - epoch_ms / 12;
        if settle > cluster.net.now_ms() {
            cluster.drive(settle);
        }
        let ledger = cluster.ledger().expect("chain enabled");
        onchain_bytes.push(ledger.onchain_bytes_of(epoch_before + 1));
        migration_bytes.push(cluster.net.total_repair_traffic() - repair_before);
    }
    EpochTrial { peers, objects, onchain_bytes, migration_bytes, avail_ok, avail_total }
}

/// Epoch-chain footprint benchmark (ISSUE 5): on-chain bytes per epoch
/// swept over stored-object count (the paper-backed claim: footprint is
/// churn-bound, never per-object) and over cluster size, plus rotation
/// migration traffic and read availability during reconfiguration.
fn cmd_bench_epoch(args: &Args) {
    let smoke = args.bool("smoke");
    let seed = args.get("seed", 7u64);
    let epochs = args.get("epochs", if smoke { 2 } else { 4u64 });
    let epoch_ms = args.get("epoch-ms", 60_000u64);
    let churn = args.get("churn", if smoke { 2 } else { 4usize });
    let object_size = args.get("size", 12_000usize);
    let out = args.str("out", "BENCH_epoch.json");
    let base_peers = if smoke { 40 } else { 96 };
    let objects_sweep: &[usize] = if smoke { &[2, 8] } else { &[4, 16, 64] };
    let nodes_sweep: &[usize] = if smoke { &[32, 48] } else { &[48, 96, 144] };
    let sweep_objects = if smoke { 4 } else { 8 };
    println!(
        "bench-epoch{}: {epochs} epochs x {epoch_ms} ms, churn {churn}/epoch, \
         objects sweep {objects_sweep:?} @ {base_peers} peers, nodes sweep {nodes_sweep:?}",
        if smoke { " (smoke)" } else { "" }
    );

    let wall = Timer::start();
    let mut obj_rows = Vec::new();
    for &objects in objects_sweep {
        let t = run_epoch_trial(base_peers, objects, epochs, epoch_ms, churn, object_size, seed);
        println!(
            "  objects {objects:>3}: {:>8.0} chain B/epoch, {:>10.0} migration B/epoch, \
             availability {:.3}",
            t.mean_onchain(),
            t.mean_migration(),
            t.availability()
        );
        obj_rows.push(t);
    }
    let mut node_rows = Vec::new();
    for &peers in nodes_sweep {
        let t =
            run_epoch_trial(peers, sweep_objects, epochs, epoch_ms, churn, object_size, seed);
        println!(
            "  peers {peers:>4}: {:>9.0} chain B/epoch, {:>10.0} migration B/epoch, \
             availability {:.3}",
            t.mean_onchain(),
            t.mean_migration(),
            t.availability()
        );
        node_rows.push(t);
    }

    // The headline claim: on-chain bytes/epoch must not grow with the
    // number of stored objects (placement is sampled, never recorded).
    let means: Vec<f64> = obj_rows.iter().map(|t| t.mean_onchain()).collect();
    let max = means.iter().cloned().fold(f64::MIN, f64::max);
    let min = means.iter().cloned().fold(f64::MAX, f64::min);
    let ratio = max / min.max(1e-9);
    let independent = ratio <= 1.05;
    let avail_min = obj_rows
        .iter()
        .chain(node_rows.iter())
        .map(|t| t.availability())
        .fold(f64::MAX, f64::min);
    let wall_secs = wall.elapsed_s();
    let rows = |v: &[EpochTrial]| {
        let items: Vec<String> = v.iter().map(|t| format!("    {}", t.json_row())).collect();
        format!("[\n{}\n  ]", items.join(",\n"))
    };
    let json = format!(
        "{{\n  \"bench\": \"epoch_plane\",\n  \"smoke\": {smoke},\n  \"seed\": {seed},\n  \
         \"epochs_per_trial\": {epochs},\n  \"epoch_ms\": {epoch_ms},\n  \
         \"churn_per_epoch\": {churn},\n  \"object_bytes\": {object_size},\n  \
         \"objects_sweep\": {},\n  \"nodes_sweep\": {},\n  \
         \"onchain_bytes_ratio_max_over_min_across_objects\": {ratio:.4},\n  \
         \"onchain_independent_of_objects\": {independent},\n  \
         \"min_availability_during_rotation\": {avail_min:.4},\n  \
         \"wall_secs\": {wall_secs:.3}\n}}\n",
        rows(&obj_rows),
        rows(&node_rows),
    );
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
    println!(
        "on-chain bytes/epoch across object counts: max/min = {ratio:.3} \
         (independent: {independent}); min availability during rotation {avail_min:.3} \
         ({wall_secs:.1}s wall)"
    );
}

/// One rung of the scale ladder: an idle-heavy sharded cluster driven
/// for a fixed virtual span (ISSUE 9).
struct ScaleRow {
    peers: usize,
    shards: usize,
    virtual_s: u64,
    wall_s: f64,
    resident_bytes_per_peer: u64,
    events: u64,
    events_per_s: f64,
    elided_ticks: u64,
    parked_ticks: u64,
}

impl ScaleRow {
    fn wall_per_virtual(&self) -> f64 {
        self.wall_s / self.virtual_s.max(1) as f64
    }
    fn json_row(&self) -> String {
        format!(
            "{{\"peers\": {}, \"shards\": {}, \"virtual_s\": {}, \"wall_s\": {:.3}, \
             \"wall_s_per_virtual_s\": {:.4}, \"resident_bytes_per_peer\": {}, \
             \"events\": {}, \"events_per_s\": {:.0}, \"elided_ticks\": {}, \
             \"parked_ticks\": {}}}",
            self.peers,
            self.shards,
            self.virtual_s,
            self.wall_s,
            self.wall_per_virtual(),
            self.resident_bytes_per_peer,
            self.events,
            self.events_per_s,
            self.elided_ticks,
            self.parked_ticks,
        )
    }
}

fn run_scale_trial(peers: usize, shards: usize, virtual_s: u64, seed: u64) -> ScaleRow {
    use vault::codec::rateless::InnerEncoder;
    use vault::crypto::vrf;
    use vault::dht::PeerInfo;
    use vault::net::shardnet::ShardNet;
    use vault::net::simnet::SimOpts;
    use vault::proto::{ClaimVerify, VaultConfig};
    use vault::util::alloc::thread_live_bytes;

    let r = 16usize.min(peers);
    let k_inner = 4usize.min(r);
    let cfg = VaultConfig {
        k_inner,
        r_inner: r,
        k_outer: 2,
        n_outer: 3,
        n_nodes: peers,
        candidates: (3 * r).min(peers),
        claim_verify: ClaimVerify::Never,
        heartbeat_ms: 10_000,
        suspicion_ms: 30_000,
        tick_ms: 10_000,
        lazy_groups: true,
        ..Default::default()
    };
    // workers = 1 keeps every allocation on this thread so the live-byte
    // gauge sees the whole runtime; the trajectory is identical at any
    // worker count (tests/scale_runtime.rs).
    let opts = SimOpts { seed, workers: 1, ..Default::default() };
    let live0 = thread_live_bytes();
    let mut net = ShardNet::new(cfg, peers, opts, shards);

    // Idle-heavy population: ~1% of peers hold fragments of seeded
    // groups; the other 99% only run maintenance ticks — the case the
    // lazy runtime exists for.
    let n_groups = (peers / (100 * r)).max(1);
    let mut rng = Rng::new(seed ^ 0x5CA1E);
    for _ in 0..n_groups {
        let mut chunk = vec![0u8; 256];
        rng.fill_bytes(&mut chunk);
        let chash = Hash256::of(&chunk);
        let member_idx = rng.sample_indices(peers, r);
        let infos: Vec<PeerInfo> = member_idx.iter().map(|&i| net.peer(i).info).collect();
        let enc = InnerEncoder::new(chash, &chunk, k_inner);
        for (slot, &i) in member_idx.iter().enumerate() {
            let frag = enc.fragment(slot as u64);
            let proof = vrf::prove(&net.peer(i).key, b"bench-scale").1;
            let others: Vec<PeerInfo> =
                infos.iter().filter(|p| p.id != net.peer(i).info.id).copied().collect();
            net.peer_mut(i).force_store(0, chash, frag, proof, others);
        }
    }

    // Warm past every node's first jittered tick (and the cold-group
    // freeze scans) so residency and throughput are steady-state.
    net.run_for(25_000);
    let resident = thread_live_bytes().saturating_sub(live0);
    let ev0 = net.stats().events;
    let wall = Timer::start();
    net.run_for(virtual_s.max(1) * 1_000);
    let wall_s = wall.elapsed_s();
    let stats = net.stats();
    let events = stats.events - ev0;
    ScaleRow {
        peers,
        shards,
        virtual_s,
        wall_s,
        resident_bytes_per_peer: resident / peers.max(1) as u64,
        events,
        events_per_s: events as f64 / wall_s.max(1e-9),
        elided_ticks: stats.elided_ticks,
        parked_ticks: stats.parked_ticks,
    }
}

/// Scale-runtime benchmark (ISSUE 9): peers vs wall-s per virtual-s,
/// resident bytes/peer, and events/s on the timer-wheel runtime with
/// interned peer state and cold-group aggregation. The full ladder ends
/// at a 100k-peer idle-heavy cluster on one box; `--smoke` runs one
/// 2k-peer rung for CI.
fn cmd_bench_scale(args: &Args) {
    let smoke = args.bool("smoke");
    let seed = args.get("seed", 7u64);
    let virtual_s = args.get("virtual-s", if smoke { 10 } else { 60u64 });
    let out = args.str("out", "BENCH_scale.json");
    let ladder: Vec<(usize, usize)> =
        if smoke { vec![(2_000, 4)] } else { vec![(10_000, 16), (50_000, 32), (100_000, 64)] };
    println!(
        "bench-scale{}: lazy ticks + interned peers + cold groups, {} virtual s per rung",
        if smoke { " (smoke)" } else { "" },
        virtual_s
    );
    let wall = Timer::start();
    let mut rows = Vec::with_capacity(ladder.len());
    for &(peers, shards) in &ladder {
        let row = run_scale_trial(peers, shards, virtual_s, seed);
        println!(
            "  {:>7} peers / {:>2} shards: {:.3} wall-s/virtual-s, {:>6} B/peer resident, \
             {:>9.0} events/s, {} elided / {} parked ticks",
            row.peers,
            row.shards,
            row.wall_per_virtual(),
            row.resident_bytes_per_peer,
            row.events_per_s,
            row.elided_ticks,
            row.parked_ticks,
        );
        rows.push(row);
    }
    let wall_secs = wall.elapsed_s();
    let row_json: Vec<String> = rows.iter().map(|r| format!("    {}", r.json_row())).collect();
    let json = format!(
        "{{\n  \"bench\": \"scale_runtime\",\n  \"schema\": \"vault-bench-scale-v1\",\n  \
         \"smoke\": {smoke},\n  \"estimated\": false,\n  \"seed\": {seed},\n  \
         \"lazy_groups\": true,\n  \"workers\": 1,\n  \"rows\": [\n{}\n  ],\n  \
         \"wall_secs\": {wall_secs:.3}\n}}\n",
        row_json.join(",\n"),
    );
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
    if let Some(top) = rows.last() {
        println!(
            "{} peers: {:.3} wall-s/virtual-s, {} B/peer ({wall_secs:.1}s wall total)",
            top.peers,
            top.wall_per_virtual(),
            top.resident_bytes_per_peer
        );
    }
}

/// One read-storm trial row for `bench-read`.
struct ReadBenchRow {
    mode: &'static str,
    peers: usize,
    gets: usize,
    in_flight: usize,
    ok: usize,
    failed: usize,
    p50_ms: f64,
    p99_ms: f64,
    p999_ms: f64,
    /// Delivered object bytes per client-plane network byte spent.
    goodput_per_byte: f64,
    hedge_rate: f64,
    hedge_win_rate: f64,
    hedge_budget_denied: u64,
    cache_hit_rate: f64,
    coalesce_rate: f64,
    late_wins: u64,
    elapsed_virtual_ms: u64,
    fingerprint: u64,
}

impl ReadBenchRow {
    fn json_row(&self) -> String {
        format!(
            "{{\"mode\": \"{}\", \"peers\": {}, \"gets\": {}, \"in_flight\": {}, \
             \"ok\": {}, \"failed\": {}, \"p50_ms\": {:.1}, \"p99_ms\": {:.1}, \
             \"p999_ms\": {:.1}, \"goodput_per_byte\": {:.4}, \"hedge_rate\": {:.4}, \
             \"hedge_win_rate\": {:.4}, \"hedge_budget_denied\": {}, \
             \"cache_hit_rate\": {:.4}, \"coalesce_rate\": {:.4}, \"late_wins\": {}, \
             \"elapsed_virtual_ms\": {}, \"fingerprint\": \"{:016x}\"}}",
            self.mode,
            self.peers,
            self.gets,
            self.in_flight,
            self.ok,
            self.failed,
            self.p50_ms,
            self.p99_ms,
            self.p999_ms,
            self.goodput_per_byte,
            self.hedge_rate,
            self.hedge_win_rate,
            self.hedge_budget_denied,
            self.cache_hit_rate,
            self.coalesce_rate,
            self.late_wins,
            self.elapsed_virtual_ms,
            self.fingerprint,
        )
    }
}

/// Sum of sender-side `Purpose::Client` bytes across every peer — the
/// denominator of goodput-per-byte.
fn client_plane_bytes(cluster: &Cluster) -> u64 {
    (0..cluster.net.len()).map(|i| cluster.net.peer(i).metrics.maint.client_bytes).sum()
}

/// One `bench-read` trial: seed a zipf corpus, degrade a quarter of the
/// peers into slow-loris repliers (they serve, seven-eighths of the op
/// timeout late), then fire an open-loop get storm from one pinned
/// client — naively, or with the full ISSUE 10 read path enabled.
fn run_read_trial(
    peers: usize,
    objects: usize,
    gets: usize,
    in_flight: usize,
    interarrival_ms: f64,
    seed: u64,
    hedged: bool,
) -> ReadBenchRow {
    const OBJECT_LEN: usize = 32_768;
    let mut cfg = ClusterConfig::small_test(peers);
    cfg.seed = seed;
    if hedged {
        cfg.vault.read_ranking = true;
        cfg.vault.read_hedge = true;
        cfg.vault.hedge_budget_mtokens = 64_000;
        cfg.vault.hedge_refill_mtokens = 4_000;
        cfg.vault.read_cache_bytes = 8 << 20;
        cfg.vault.read_coalesce = true;
        cfg.vault.read_cancel = true;
    }
    let mut cluster = Cluster::start(cfg);
    let mut rng = Rng::new(seed ^ 0xBEAD);
    let mut refs = Vec::with_capacity(objects);
    for i in 0..objects {
        let mut data = vec![0u8; OBJECT_LEN];
        rng.fill_bytes(&mut data);
        let secret = format!("bench-read-{i}");
        refs.push(
            cluster.store_blocking(0, &data, secret.as_bytes(), 0).expect("seed store").value,
        );
    }
    for i in rng.sample_indices(peers, (peers / 4).max(1)) {
        cluster.net.peer_mut(i).fault.slow_loris = true;
    }
    let bytes_before = client_plane_bytes(&cluster);
    let spec = ReadStormSpec {
        seed: seed ^ 0x57_0B,
        total_gets: gets,
        target_in_flight: in_flight,
        mean_interarrival_ms: interarrival_ms,
        zipf_s: 1.1,
        deadline_ms: None,
        max_virtual_ms: 600_000,
        single_client: true,
    };
    let report = run_read_storm(&mut cluster, &spec, &refs);
    let net_bytes = client_plane_bytes(&cluster).saturating_sub(bytes_before);
    let (mut hedges, mut wins, mut denied) = (0u64, 0u64, 0u64);
    let (mut hits, mut misses, mut coalesced, mut late) = (0u64, 0u64, 0u64, 0u64);
    for i in 0..cluster.net.len() {
        let m = &cluster.net.peer(i).metrics;
        hedges += m.hedges_issued;
        wins += m.hedge_wins;
        denied += m.hedge_budget_denied;
        hits += m.read_cache_hits;
        misses += m.read_cache_misses;
        coalesced += m.coalesced_gets;
        late += m.late_wins;
    }
    let submitted = report.submitted.max(1) as f64;
    ReadBenchRow {
        mode: if hedged { "hedged" } else { "naive" },
        peers,
        gets: report.submitted,
        in_flight,
        ok: report.ok,
        failed: report.failed,
        p50_ms: report.p(50.0),
        p99_ms: report.p(99.0),
        p999_ms: report.p(99.9),
        goodput_per_byte: report.bytes_fetched as f64 / net_bytes.max(1) as f64,
        hedge_rate: hedges as f64 / submitted,
        hedge_win_rate: wins as f64 / hedges.max(1) as f64,
        hedge_budget_denied: denied,
        cache_hit_rate: hits as f64 / (hits + misses).max(1) as f64,
        coalesce_rate: coalesced as f64 / submitted,
        late_wins: late,
        elapsed_virtual_ms: report.elapsed_virtual_ms,
        fingerprint: report.fingerprint,
    }
}

/// Heavy-traffic read-path benchmark (ISSUE 10): the same zipf get
/// storm runs naive (seed-era fan-out) and with replica ranking +
/// hedged requests + hot-object caching + request coalescing, against
/// a cluster whose nearer replicas are slow. The full ladder holds
/// 10k+ gets in flight; `--smoke` runs a 300-get storm for CI.
fn cmd_bench_read(args: &Args) {
    let smoke = args.bool("smoke");
    let seed = args.get("seed", 7u64);
    let peers = args.get("peers", if smoke { 48 } else { 96usize });
    let gets = args.get("gets", if smoke { 300 } else { 12_000usize });
    let in_flight = args.get("inflight", if smoke { 32 } else { 10_000usize });
    let objects = if smoke { 12 } else { 64 };
    let interarrival_ms = if smoke { 10.0 } else { 0.05 };
    let out = args.str("out", "BENCH_read.json");
    println!(
        "bench-read{}: {} zipf gets, {} in flight, {} peers (quarter slow-loris), naive vs hedged",
        if smoke { " (smoke)" } else { "" },
        gets,
        in_flight,
        peers
    );
    let wall = Timer::start();
    let rows = vec![
        run_read_trial(peers, objects, gets, in_flight, interarrival_ms, seed, false),
        run_read_trial(peers, objects, gets, in_flight, interarrival_ms, seed, true),
    ];
    for r in &rows {
        println!(
            "  {:>6}: p50 {:>6.0}ms p99 {:>6.0}ms p999 {:>6.0}ms, {:.4} goodput/B, \
             hedge {:.3}/get (win {:.2}), cache hit {:.3}, coalesce {:.3}, {} ok / {} failed",
            r.mode,
            r.p50_ms,
            r.p99_ms,
            r.p999_ms,
            r.goodput_per_byte,
            r.hedge_rate,
            r.hedge_win_rate,
            r.cache_hit_rate,
            r.coalesce_rate,
            r.ok,
            r.failed,
        );
    }
    let wall_secs = wall.elapsed_s();
    let p99_speedup = rows[0].p99_ms / rows[1].p99_ms.max(1e-9);
    let row_json: Vec<String> = rows.iter().map(|r| format!("    {}", r.json_row())).collect();
    let json = format!(
        "{{\n  \"bench\": \"read_path\",\n  \"schema\": \"vault-bench-read-v1\",\n  \
         \"smoke\": {smoke},\n  \"estimated\": false,\n  \"seed\": {seed},\n  \
         \"p99_speedup\": {p99_speedup:.2},\n  \"rows\": [\n{}\n  ],\n  \
         \"wall_secs\": {wall_secs:.3}\n}}\n",
        row_json.join(",\n"),
    );
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
    println!(
        "hedged read path: p99 {:.0}ms vs naive {:.0}ms ({p99_speedup:.1}x) ({wall_secs:.1}s wall)",
        rows[1].p99_ms, rows[0].p99_ms
    );
}

/// Build a SimNet whose peers each hold ~`chunks_per_node` fragments of
/// real (hash-verifiable) seeded chunk groups — the bench-maint seeding
/// recipe — and warm it past the first maintenance tick so every WAL
/// holds its inventory plus at least one membership flush.
fn seeded_restart_net(
    peers: usize,
    chunks_per_node: usize,
    r: usize,
    seed: u64,
) -> (vault::net::simnet::SimNet, Vec<Hash256>) {
    use vault::codec::rateless::InnerEncoder;
    use vault::crypto::vrf;
    use vault::dht::PeerInfo;
    use vault::net::simnet::{SimNet, SimOpts};
    use vault::proto::{ClaimVerify, VaultConfig};

    let k_inner = 4usize.min(r);
    let cfg = VaultConfig {
        k_inner,
        r_inner: r,
        k_outer: 2,
        n_outer: 3,
        n_nodes: peers,
        candidates: (3 * r).min(peers),
        claim_verify: ClaimVerify::Never,
        heartbeat_ms: 10_000,
        suspicion_ms: 30_000,
        tick_ms: 10_000,
        ..Default::default()
    };
    let opts = SimOpts { seed, ..Default::default() };
    let mut net = SimNet::new(cfg, peers, opts);
    let n_groups = (peers * chunks_per_node / r).max(1);
    let mut rng = Rng::new(seed ^ 0x2EB0);
    let mut chashes = Vec::with_capacity(n_groups);
    for _ in 0..n_groups {
        let mut chunk = vec![0u8; 256];
        rng.fill_bytes(&mut chunk);
        let chash = Hash256::of(&chunk);
        chashes.push(chash);
        let member_idx = rng.sample_indices(peers, r);
        let infos: Vec<PeerInfo> = member_idx.iter().map(|&i| net.peer(i).info).collect();
        let enc = InnerEncoder::new(chash, &chunk, k_inner);
        for (slot, &i) in member_idx.iter().enumerate() {
            let frag = enc.fragment(slot as u64);
            let proof = vrf::prove(&net.peer(i).key, b"bench-restart").1;
            let others: Vec<PeerInfo> =
                infos.iter().filter(|p| p.id != net.peer(i).info.id).copied().collect();
            net.peer_mut(i).force_store(0, chash, frag, proof, others);
        }
    }
    net.run_for(25_000);
    (net, chashes)
}

/// One restart wave over a freshly seeded net: restart `count` peers
/// (torn tails or clean), count chunks below the decode threshold right
/// after the wave (durability loss), then drive to full re-convergence.
struct RestartWave {
    restarted: usize,
    replayed_records: u64,
    torn_records_lost: u64,
    torn_bytes: u64,
    durability_loss_chunks: usize,
    reconverge_virtual_ms: u64,
    converged: bool,
}

fn run_restart_wave(
    peers: usize,
    chunks_per_node: usize,
    r: usize,
    seed: u64,
    count: usize,
    torn: bool,
) -> RestartWave {
    let (mut net, chashes) = seeded_restart_net(peers, chunks_per_node, r, seed);
    let k_inner = 4usize.min(r);
    let mut wave = RestartWave {
        restarted: 0,
        replayed_records: 0,
        torn_records_lost: 0,
        torn_bytes: 0,
        durability_loss_chunks: 0,
        reconverge_virtual_ms: 0,
        converged: false,
    };
    let mut rng = Rng::new(seed ^ 0x7042);
    for _ in 0..count {
        let i = rng.range(0, peers);
        let records_before = net.peer(i).wal.next_sequence();
        let cut = if torn {
            let (start, end) = net.peer(i).wal.tail_span();
            (end > start + 1).then(|| start + 1 + rng.next_u64() % (end - start - 1))
        } else {
            None
        };
        let report = net.restart(i, cut);
        wave.restarted += 1;
        wave.replayed_records += report.replayed;
        wave.torn_records_lost += records_before - report.replayed;
        wave.torn_bytes += report.torn_tail_bytes;
    }
    wave.durability_loss_chunks =
        chashes.iter().filter(|c| net.surviving_fragments(c) < k_inner).count();
    let start = net.now_ms();
    let deadline = start + 40 * 60_000;
    while net.now_ms() < deadline {
        if chashes.iter().all(|c| net.surviving_fragments(c) >= r) {
            wave.converged = true;
            break;
        }
        net.run_for(10_000);
    }
    wave.reconverge_virtual_ms = net.now_ms() - start;
    wave
}

/// Crash-restart recovery benchmark (ISSUE 6). Three measurements:
/// recovery cost vs stored chunks (wall-ms per restart + replayed
/// records/s, swept over chunks-per-node), a clean restart wave, and a
/// torn-tail restart wave — both waves asserting zero durability loss
/// and reporting bounded re-convergence in virtual time.
fn cmd_bench_restart(args: &Args) {
    let smoke = args.bool("smoke");
    let peers = args.get("peers", if smoke { 32 } else { 64usize });
    let r = args.get("r", 16usize);
    let seed = args.get("seed", 7u64);
    let out = args.str("out", "BENCH_restart.json");
    let chunks_sweep: &[usize] = if smoke { &[4, 8] } else { &[8, 32, 64] };
    let wave_count = (peers / 4).max(1);
    println!(
        "bench-restart{}: {peers} peers, R={r}, chunks/node sweep {chunks_sweep:?}, \
         waves of {wave_count}",
        if smoke { " (smoke)" } else { "" }
    );

    let wall = Timer::start();
    // Recovery-cost sweep: one peer restarted per seeded net, wall time
    // bracketing exactly the WAL replay + rebuild + re-announce work.
    let mut sweep_rows = Vec::new();
    for &cpn in chunks_sweep {
        let (mut net, _) = seeded_restart_net(peers, cpn, r, seed);
        let victim = 0usize;
        let records = net.peer(victim).wal.next_sequence();
        let t = Timer::start();
        let report = net.restart(victim, None);
        let recovery_wall_ms = t.elapsed_s() * 1e3;
        let replayed_per_sec = report.replayed as f64 / (recovery_wall_ms / 1e3).max(1e-9);
        let recovered = net.peer(victim).metrics.recovered_fragments;
        println!(
            "  chunks/node {cpn:>3}: {records:>5} wal records, {recovery_wall_ms:>8.3} ms \
             recovery, {replayed_per_sec:>12.0} records/s, {recovered} fragments back"
        );
        sweep_rows.push(format!(
            "{{\"chunks_per_node\": {cpn}, \"wal_records\": {records}, \
             \"recovery_wall_ms\": {recovery_wall_ms:.4}, \
             \"replayed_per_sec\": {replayed_per_sec:.0}, \
             \"recovered_fragments\": {recovered}}}"
        ));
    }
    let cpn = chunks_sweep[chunks_sweep.len() / 2];

    let clean = run_restart_wave(peers, cpn, r, seed, wave_count, false);
    println!(
        "  clean wave: {} restarts, {} records replayed, {} chunks lost, \
         reconverge {} virtual ms{}",
        clean.restarted,
        clean.replayed_records,
        clean.durability_loss_chunks,
        clean.reconverge_virtual_ms,
        if clean.converged { "" } else { " (NOT converged)" }
    );
    let torn = run_restart_wave(peers, cpn, r, seed ^ 1, wave_count, true);
    println!(
        "  torn wave : {} restarts, {} records replayed, {} tail records lost \
         ({} B), {} chunks lost, reconverge {} virtual ms{}",
        torn.restarted,
        torn.replayed_records,
        torn.torn_records_lost,
        torn.torn_bytes,
        torn.durability_loss_chunks,
        torn.reconverge_virtual_ms,
        if torn.converged { "" } else { " (NOT converged)" }
    );

    let wave_json = |w: &RestartWave| {
        format!(
            "{{\"restarted\": {}, \"replayed_records\": {}, \"torn_records_lost\": {}, \
             \"torn_bytes\": {}, \"durability_loss_chunks\": {}, \
             \"reconverge_virtual_ms\": {}, \"converged\": {}}}",
            w.restarted,
            w.replayed_records,
            w.torn_records_lost,
            w.torn_bytes,
            w.durability_loss_chunks,
            w.reconverge_virtual_ms,
            w.converged,
        )
    };
    let wall_secs = wall.elapsed_s();
    let sweep = format!("[\n    {}\n  ]", sweep_rows.join(",\n    "));
    let json = format!(
        "{{\n  \"bench\": \"restart_recovery\",\n  \"smoke\": {smoke},\n  \"seed\": {seed},\n  \
         \"peers\": {peers},\n  \"r_inner\": {r},\n  \"wave_restarts\": {wave_count},\n  \
         \"recovery_sweep\": {sweep},\n  \
         \"clean_wave\": {},\n  \"torn_wave\": {},\n  \"wall_secs\": {wall_secs:.3}\n}}\n",
        wave_json(&clean),
        wave_json(&torn),
    );
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
    println!(
        "durability loss: clean {} chunks, torn {} chunks (both must be 0); \
         ({wall_secs:.1}s wall)",
        clean.durability_loss_chunks, torn.durability_loss_chunks
    );
}

/// One audit-plane trial: a seeded epoch-chain cluster with a cluster
/// of fragment withholders, driven boundary-to-boundary until every
/// withholder is suspected by at least `need_suspecters` honest peers
/// (or the epoch budget runs out).
struct AuditTrial {
    rate: f64,
    epochs_run: u64,
    /// Boundaries crossed from withhold injection until every
    /// withholder was broadly suspected (`None` = not within budget).
    detection_epochs: Option<u64>,
    audit_bytes_per_node_epoch: f64,
    audit_msgs_per_node_epoch: f64,
    /// Suspect entries pointing at peers that are *not* withholders —
    /// the zero-false-positive contract, counted across every ledger.
    false_positives: usize,
}

fn run_audit_trial(
    peers: usize,
    objects: usize,
    withhold: usize,
    rate: f64,
    max_epochs: u64,
    seed: u64,
) -> AuditTrial {
    use vault::dht::NodeId;
    const NEED_SUSPECTERS: usize = 3;
    let epoch_ms = 60_000u64;
    let mut cfg = ClusterConfig::small_test(peers);
    cfg.seed = seed;
    cfg.epoch_ms = epoch_ms;
    cfg.vault.rotation_grace_ms = 20_000;
    cfg.vault.heartbeat_ms = 5_000;
    cfg.vault.suspicion_ms = 15_000;
    cfg.vault.tick_ms = 5_000;
    cfg.vault.audits = true;
    cfg.vault.audit_rate = rate;
    let mut cluster = Cluster::start(cfg);
    let mut rng = Rng::new(seed ^ 0xA0D17);
    let mut first_chunk = None;
    for o in 0..objects {
        let mut data = vec![0u8; 12_000];
        rng.fill_bytes(&mut data);
        let client = cluster.random_client();
        let id = cluster
            .store_blocking(client, &data, format!("audit-bench-{o}").as_bytes(), 0)
            .expect("seed store")
            .value;
        if o == 0 {
            first_chunk = Some(id.chunks[0]);
        }
    }
    let chash = first_chunk.expect("at least one object");

    // Cluster the withholders inside one chunk's group (the hard case:
    // correlated retrievability loss), though `refuse_frags` withholds
    // *everything* they store.
    let mut withheld: Vec<NodeId> = Vec::new();
    for i in 0..cluster.net.len() {
        if withheld.len() >= withhold {
            break;
        }
        if cluster.net.is_up(i) && cluster.net.peer(i).fragment_index(&chash).is_some() {
            cluster.net.peer_mut(i).fault.refuse_frags = true;
            withheld.push(cluster.net.peer(i).id());
        }
    }

    let all_suspected = |cluster: &Cluster<vault::net::simnet::SimNet>| {
        withheld.iter().all(|wid| {
            let suspecters = (0..cluster.net.len())
                .filter(|&i| cluster.net.is_up(i))
                .filter(|&i| !cluster.net.peer(i).fault.refuse_frags)
                .filter(|&i| cluster.net.peer(i).id() != *wid)
                .filter(|&i| cluster.net.peer(i).is_audit_suspect(wid))
                .count();
            suspecters >= NEED_SUSPECTERS
        })
    };

    let before = cluster.net.maint_stats();
    let mut detection_epochs = None;
    let mut epochs_run = 0u64;
    for e in 1..=max_epochs {
        // Cross the next boundary, then give the verdict gossip and the
        // boundary's ledger advance a settle window.
        let boundary = ((cluster.net.now_ms() / epoch_ms) + 1) * epoch_ms;
        cluster.drive(boundary + 5_000);
        epochs_run = e;
        if all_suspected(&cluster) {
            detection_epochs = Some(e);
            break;
        }
    }
    let after = cluster.net.maint_stats();
    let audit_bytes = after.audit_bytes - before.audit_bytes;
    let audit_msgs = after.audit_msgs - before.audit_msgs;
    let denom = (peers as f64) * (epochs_run.max(1) as f64);

    let mut false_positives = 0usize;
    for i in 0..cluster.net.len() {
        if !cluster.net.is_up(i) {
            continue;
        }
        for s in cluster.net.peer(i).audit_suspects() {
            if !withheld.contains(&s) {
                false_positives += 1;
            }
        }
    }

    AuditTrial {
        rate,
        epochs_run,
        detection_epochs,
        audit_bytes_per_node_epoch: audit_bytes as f64 / denom,
        audit_msgs_per_node_epoch: audit_msgs as f64 / denom,
        false_positives,
    }
}

/// Retrievability audit plane benchmark (ISSUE 7): detection latency of
/// a withholding cluster vs audit sampling rate, audit traffic per node
/// per epoch, and the zero-false-positive contract — all three land in
/// `BENCH_audit.json` for CI schema validation.
fn cmd_bench_audit(args: &Args) {
    let smoke = args.bool("smoke");
    let peers = args.get("peers", if smoke { 32 } else { 48usize });
    let objects = if smoke { 2 } else { 4usize };
    let withhold = args.get("withhold", if smoke { 2 } else { 4usize });
    let max_epochs = args.get("epochs", if smoke { 6 } else { 8u64 });
    let seed = args.get("seed", 7u64);
    let out = args.str("out", "BENCH_audit.json");
    let rates: &[f64] = if smoke { &[0.25, 0.5] } else { &[0.1, 0.25, 0.5] };
    println!(
        "bench-audit{}: {peers} peers, {objects} objects, {withhold} withholders, \
         rate sweep {rates:?}, budget {max_epochs} epochs",
        if smoke { " (smoke)" } else { "" }
    );

    let wall = Timer::start();
    let mut rows = Vec::new();
    let mut fp_total = 0usize;
    for &rate in rates {
        let t = run_audit_trial(peers, objects, withhold, rate, max_epochs, seed);
        let detect = t
            .detection_epochs
            .map(|e| e.to_string())
            .unwrap_or_else(|| "null".into());
        println!(
            "  rate {rate:>4}: detection {} epochs, {:>8.0} audit B/node/epoch, \
             {:>6.1} audit msgs/node/epoch, {} false positives",
            t.detection_epochs.map(|e| e as i64).unwrap_or(-1),
            t.audit_bytes_per_node_epoch,
            t.audit_msgs_per_node_epoch,
            t.false_positives
        );
        fp_total += t.false_positives;
        rows.push(format!(
            "{{\"rate\": {rate}, \"epochs_run\": {}, \"detected\": {}, \
             \"detection_epochs\": {detect}, \
             \"audit_bytes_per_node_per_epoch\": {:.1}, \
             \"audit_msgs_per_node_per_epoch\": {:.2}, \
             \"false_positives\": {}}}",
            t.epochs_run,
            t.detection_epochs.is_some(),
            t.audit_bytes_per_node_epoch,
            t.audit_msgs_per_node_epoch,
            t.false_positives,
        ));
    }
    let wall_secs = wall.elapsed_s();
    let trials = format!("[\n    {}\n  ]", rows.join(",\n    "));
    let json = format!(
        "{{\n  \"bench\": \"audit_plane\",\n  \"smoke\": {smoke},\n  \"seed\": {seed},\n  \
         \"peers\": {peers},\n  \"objects\": {objects},\n  \"withholders\": {withhold},\n  \
         \"epoch_ms\": 60000,\n  \"epoch_budget\": {max_epochs},\n  \
         \"need_suspecters\": 3,\n  \"trials\": {trials},\n  \
         \"false_positives_total\": {fp_total},\n  \"wall_secs\": {wall_secs:.3}\n}}\n",
    );
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
    println!(
        "audit plane: {} trials, {fp_total} false positives (must be 0) ({wall_secs:.1}s wall)",
        rates.len()
    );
}

/// One adversarial fault family measured as a defenses-off /
/// defenses-on twin (ISSUE 8).
struct AdversaryRow {
    family: &'static str,
    /// What the detection signal counts for this family.
    signal: &'static str,
    signal_off: u64,
    signal_on: u64,
    avail_off_ppm: u64,
    avail_on_ppm: u64,
    /// Upper bound on detection latency: the phase window the signal
    /// formed within.
    window_ms: u64,
    /// Honest peers greylisted or quarantined anywhere, summed over
    /// both twins — the zero-false-greylist contract.
    false_greylists: u64,
}

/// Availability floor for a phase: flash-crowd success fraction when a
/// crowd ran, else full marks iff the `AllObjectsReadable` check held
/// (a failed check fails the whole bench run loudly before this).
fn adversary_avail_ppm(p: &vault::sim::scenario::PhaseOutcome) -> u64 {
    let total = p.crowd_ok + p.crowd_failed;
    if total > 0 {
        p.crowd_ok as u64 * 1_000_000 / total as u64
    } else {
        1_000_000
    }
}

fn run_adversary_twin(
    family: &'static str,
    signal: &'static str,
    mk: &dyn Fn(bool) -> vault::sim::scenario::ScenarioSpec,
    pick: &dyn Fn(&vault::sim::scenario::PhaseOutcome) -> u64,
) -> AdversaryRow {
    use vault::sim::scenario::run_scenario;
    let (off_spec, on_spec) = (mk(false), mk(true));
    let window_ms = off_spec.phases.iter().map(|p| p.advance_ms).sum();
    let off = run_scenario(&off_spec);
    let on = run_scenario(&on_spec);
    for r in [&off, &on] {
        assert!(
            r.ok(),
            "adversary bench `{}` violated invariants:\n  {}",
            r.name,
            r.failures().join("\n  ")
        );
    }
    let last_off = off.phases.last().expect("twin has a phase");
    let last_on = on.phases.last().expect("twin has a phase");
    AdversaryRow {
        family,
        signal,
        signal_off: pick(last_off),
        signal_on: pick(last_on),
        avail_off_ppm: adversary_avail_ppm(last_off),
        avail_on_ppm: adversary_avail_ppm(last_on),
        window_ms,
        false_greylists: (last_off.honest_greylisted + last_on.honest_greylisted) as u64,
    }
}

/// Adversarial resilience plane benchmark (ISSUE 8): every fault family
/// runs as an off/on twin over the same seed and fault schedule; the
/// defense must strictly improve the family's detection signal while
/// never greylisting an honest peer. The five rows, the availability
/// floors, and the zero-false-greylist total land in
/// `BENCH_adversary.json` for CI schema validation.
fn cmd_bench_adversary(args: &Args) {
    use vault::sim::scenario::{Check, Fault, ScenarioSpec};
    let smoke = args.bool("smoke");
    let seed = args.get("seed", 7u64);
    let out = args.str("out", "BENCH_adversary.json");
    // Smoke trims the measurement load (fewer lookups / readers), never
    // the fault intensity — the defenses face the same adversary.
    let lookups = if smoke { 24 } else { 40usize };
    let readers = if smoke { 8 } else { 16usize };
    println!(
        "bench-adversary{}: 5 fault families, off/on twins, seed {seed}",
        if smoke { " (smoke)" } else { "" }
    );

    let wall = Timer::start();
    let mut rows: Vec<AdversaryRow> = Vec::new();

    rows.push(run_adversary_twin(
        "eclipse",
        "honest_reach_ppm",
        &|ph| {
            let mut s = ScenarioSpec::small("bench_eclipse", seed ^ 0xEC5E, 100);
            if ph {
                s = s.peer_health();
            }
            s.phase(
                "poison-and-measure",
                vec![Fault::Eclipse { sybils: 300, lookups }],
                20_000,
                vec![Check::AllObjectsReadable, Check::NoHonestGreylisted],
            )
        },
        &|p| p.eclipse_reach_ppm,
    ));

    rows.push(run_adversary_twin(
        "beacon_equivocate",
        "quarantining_observers",
        &|ph| {
            let mut s = ScenarioSpec::small("bench_equivocate", seed ^ 0xE0C1, 40)
                .epoch_rotation(60_000, 20_000);
            if ph {
                s = s.peer_health();
            }
            s.phase(
                "fork-the-beacon",
                vec![Fault::BeaconEquivocate],
                30_000,
                vec![
                    Check::EquivocatorQuarantined { min_frac: if ph { 0.5 } else { 0.0 } },
                    Check::NoHonestGreylisted,
                    Check::AllObjectsReadable,
                ],
            )
        },
        &|p| p.quarantiners as u64,
    ));

    rows.push(run_adversary_twin(
        "censor_object",
        "audit_suspect_pairs",
        &|ph| {
            let mut s =
                ScenarioSpec::small("bench_censor", seed ^ 0xCE45, 48).epoch_rotation(60_000, 20_000);
            let mut checks = vec![Check::AllObjectsReadable];
            if ph {
                // The audit plane is the defense against polite refusal;
                // the health plane rides along to prove the refusal
                // produces zero offenses and zero greylists.
                s = s.audits(0.5).peer_health();
                checks.extend([
                    Check::FaultedAuditSuspectersWithin { min: 3, max: 48 },
                    Check::NoHonestSuspected,
                    Check::NoHonestGreylisted,
                    Check::HealthOffensesWithin { min: 0, max: 0 },
                    Check::GreylistsWithin { min: 0, max: 0 },
                ]);
            } else {
                checks.push(Check::FaultedAuditSuspectersWithin { min: 0, max: 0 });
            }
            s.phase(
                "censor-one-chunk",
                vec![Fault::CensorObject { object: 0, chunk: 0, members: 6 }],
                260_000,
                checks,
            )
        },
        &|p| p.suspect_pairs as u64,
    ));

    rows.push(run_adversary_twin(
        "slow_loris",
        "health_offenses",
        &|ph| {
            let mut s = ScenarioSpec::small("bench_slow_loris", seed ^ 0x510B, 40);
            if ph {
                s = s.peer_health();
            }
            s.phase(
                "trickle-under-crowd",
                vec![
                    Fault::SlowLoris { object: 0, chunk: 0, members: 13 },
                    Fault::FlashCrowd { object: 0, readers },
                ],
                30_000,
                vec![
                    Check::AllObjectsReadable,
                    Check::HealthOffensesWithin {
                        min: if ph { 1 } else { 0 },
                        max: if ph { u64::MAX } else { 0 },
                    },
                    Check::NoHonestGreylisted,
                ],
            )
        },
        &|p| p.health_offenses,
    ));

    rows.push(run_adversary_twin(
        "adaptive_withhold",
        "health_offenses",
        &|ph| {
            let mut s = ScenarioSpec::small("bench_adaptive", seed ^ 0xAD47, 48)
                .epoch_rotation(60_000, 20_000)
                .audits(0.5);
            if ph {
                s = s.peer_health();
            }
            s.phase(
                "duty-cycle-withholding",
                vec![
                    Fault::AdaptiveWithhold { object: 0, chunk: 0, members: 10 },
                    Fault::FlashCrowd { object: 0, readers },
                ],
                260_000,
                vec![
                    // Audits stay green in BOTH twins — the family
                    // exists because only deadline accounting sees it.
                    Check::FaultedAuditSuspectersWithin { min: 0, max: 0 },
                    Check::NoHonestSuspected,
                    Check::HealthOffensesWithin {
                        min: if ph { 1 } else { 0 },
                        max: if ph { u64::MAX } else { 0 },
                    },
                    Check::NoHonestGreylisted,
                    Check::AllObjectsReadable,
                ],
            )
        },
        &|p| p.health_offenses,
    ));

    let mut json_rows = Vec::new();
    let mut false_greylists_total = 0u64;
    for r in &rows {
        println!(
            "  {:<18} {}: off {:>8} -> on {:>8} | avail {:>7}/{:<7} ppm | window {:>6} ms | {} false greylists",
            r.family,
            r.signal,
            r.signal_off,
            r.signal_on,
            r.avail_off_ppm,
            r.avail_on_ppm,
            r.window_ms,
            r.false_greylists
        );
        false_greylists_total += r.false_greylists;
        json_rows.push(format!(
            "{{\"family\": \"{}\", \"signal\": \"{}\", \"signal_off\": {}, \
             \"signal_on\": {}, \"availability_off_ppm\": {}, \
             \"availability_on_ppm\": {}, \"detection_window_ms\": {}, \
             \"false_greylists\": {}}}",
            r.family,
            r.signal,
            r.signal_off,
            r.signal_on,
            r.avail_off_ppm,
            r.avail_on_ppm,
            r.window_ms,
            r.false_greylists
        ));
    }
    assert_eq!(false_greylists_total, 0, "an honest peer was greylisted or quarantined");

    let wall_secs = wall.elapsed_s();
    let families = format!("[\n    {}\n  ]", json_rows.join(",\n    "));
    let json = format!(
        "{{\n  \"bench\": \"adversary_plane\",\n  \"smoke\": {smoke},\n  \"seed\": {seed},\n  \
         \"families\": {families},\n  \
         \"false_greylists_total\": {false_greylists_total},\n  \
         \"wall_secs\": {wall_secs:.3}\n}}\n",
    );
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
    println!(
        "adversary plane: {} families, {false_greylists_total} false greylists (must be 0) \
         ({wall_secs:.1}s wall)",
        rows.len()
    );
}

fn cmd_cluster(args: &Args) {
    let peers = args.get("peers", 128usize);
    let objects = args.get("objects", 4usize);
    let size = args.get("size", 256 * 1024usize);
    let byz = args.get("byzantine", 0.0f64);
    let churn = args.get("churn", 0usize);

    let mut cfg = ClusterConfig::small_test(peers);
    cfg.byzantine_frac = byz;
    println!(
        "cluster: {peers} peers x5 regions, inner ({},{}), outer ({},{}), byz {byz}",
        cfg.vault.k_inner, cfg.vault.r_inner, cfg.vault.k_outer, cfg.vault.n_outer
    );
    let mut cluster = Cluster::start(cfg);
    let corpus = Corpus::generate(1, objects, size);
    let wall = Timer::start();
    let mut ids = Vec::new();
    for (i, (data, secret)) in corpus.objects.iter().enumerate() {
        let client = cluster.random_client();
        match cluster.store_blocking(client, data, secret, 0) {
            Ok(res) => {
                println!("store #{i}: {} ms (virtual)", res.latency_ms);
                ids.push((res.value, data.clone()));
            }
            Err(e) => println!("store #{i} FAILED: {e}"),
        }
    }
    if churn > 0 {
        println!("churning {churn} peers and letting repair run...");
        cluster.churn(churn);
        cluster.net.run_for(600_000);
    }
    for (i, (id, want)) in ids.iter().enumerate() {
        let client = cluster.random_client();
        match cluster.query_blocking(client, id) {
            Ok(res) => {
                let ok = &res.value == want;
                println!("query #{i}: {} ms (virtual), intact={ok}", res.latency_ms);
                assert!(ok, "data corruption");
            }
            Err(e) => println!("query #{i} FAILED: {e}"),
        }
    }
    println!(
        "done in {:.1}s wall; virtual time {} s; net msgs {} bytes {}",
        wall.elapsed_s(),
        cluster.net.now_ms() / 1000,
        cluster.net.stats.msgs,
        cluster.net.stats.bytes
    );
}

fn cmd_tcp_demo(args: &Args) {
    use vault::net::tcp::TcpCluster;
    let peers = args.get("peers", 8usize);
    let size = args.get("size", 65536usize);
    let mut cfg = ClusterConfig::small_test(peers).vault;
    cfg.k_inner = 4;
    cfg.r_inner = peers.min(6);
    cfg.k_outer = 2;
    cfg.n_outer = 3;
    cfg.op_timeout_ms = 1000;
    println!("starting {peers} TCP nodes on localhost...");
    let cluster = TcpCluster::start(cfg, peers, 5).expect("cluster up");
    let mut rng = Rng::new(9);
    let mut data = vec![0u8; size];
    rng.fill_bytes(&mut data);
    let wall = Timer::start();
    let op = cluster.nodes[0].store(data.clone(), b"tcp-secret".to_vec(), 0);
    let ev = cluster.nodes[0]
        .wait_op(op, std::time::Duration::from_secs(30))
        .expect("store completes");
    let id = match ev {
        vault::proto::AppEvent::StoreDone { id, latency_ms, .. } => {
            println!("store: {latency_ms} ms");
            id
        }
        other => panic!("store failed: {other:?}"),
    };
    let op = cluster.nodes[1].query(&id);
    match cluster.nodes[1].wait_op(op, std::time::Duration::from_secs(30)) {
        Some(vault::proto::AppEvent::QueryDone { data: got, latency_ms, .. }) => {
            println!("query: {latency_ms} ms, intact={}", got == data);
            assert_eq!(got, data);
        }
        other => panic!("query failed: {other:?}"),
    }
    println!("tcp round trip OK in {:.1}s wall", wall.elapsed_s());
    cluster.shutdown();
}

fn cmd_sim(args: &Args) {
    let fig = args.get("fig", 4usize);
    let nodes = args.get("nodes", 100_000usize);
    let objects = args.get("objects", 1000usize);
    let churn = args.get("churn", 2.0f64);
    let years = args.get("years", 1.0f64);
    let seed = args.get("seed", 42u64);
    match fig {
        4 => {
            for cache in [0.0, 24.0, 48.0] {
                let cfg = durability::SimConfig {
                    n_nodes: nodes,
                    n_objects: objects,
                    churn_per_year: churn,
                    cache_ttl_hours: cache,
                    duration_years: years,
                    seed,
                    ..Default::default()
                };
                let r = durability::run(&cfg);
                println!(
                    "vault cache={cache:>4}h: traffic={:.1} obj-units repairs={} hits={} lost={}",
                    r.repair_traffic_objects, r.repairs, r.cache_hits, r.lost_objects
                );
            }
            let rep = replica::run(&replica::ReplicaConfig {
                n_nodes: nodes,
                n_objects: objects,
                churn_per_year: churn,
                duration_years: years,
                seed,
                ..Default::default()
            });
            println!(
                "replicated baseline: traffic={:.1} obj-units repairs={} lost={}",
                rep.repair_traffic_objects, rep.repairs, rep.lost_objects
            );
        }
        5 => {
            for (k, r) in [(32usize, 80usize), (32, 48)] {
                let cfg = durability::SimConfig {
                    n_nodes: nodes,
                    n_objects: 1,
                    k_inner: k,
                    r_inner: r,
                    churn_per_year: churn,
                    duration_years: years.max(10.0),
                    trace: true,
                    seed,
                    ..Default::default()
                };
                let rep = durability::run(&cfg);
                println!("config ({k},{r}): trace of honest fragments (hours,count):");
                for (t, c) in rep.trace.iter().step_by(4) {
                    println!("  {t:>9.0} {c}");
                }
            }
        }
        6 => {
            println!("byzantine sweep (1-year loss fraction):");
            for f in [0.0, 0.1, 0.2, 0.3, 0.4, 0.5] {
                let r = durability::run(&durability::SimConfig {
                    n_nodes: nodes,
                    n_objects: objects,
                    churn_per_year: churn.max(4.0),
                    byzantine_frac: f,
                    duration_years: years,
                    seed,
                    ..Default::default()
                });
                let b = replica::run(&replica::ReplicaConfig {
                    n_nodes: nodes,
                    n_objects: objects,
                    churn_per_year: churn.max(4.0),
                    byzantine_frac: f,
                    duration_years: years,
                    seed,
                    ..Default::default()
                });
                println!(
                    "  byz={f:.2}: vault lost {:.3} | baseline lost {:.3}",
                    r.lost_object_frac, b.lost_object_frac
                );
            }
            println!("targeted-attack sweep:");
            for frac in [0.02, 0.05, 0.1, 0.2, 0.3] {
                let v = attack::vault_attack_loss(&attack::AttackConfig {
                    n_nodes: nodes,
                    n_objects: objects,
                    attacked_frac: frac,
                    ..Default::default()
                });
                let b = attack::baseline_attack_loss(nodes, objects, 256, 3, frac, seed);
                println!("  attacked={frac:.2}: vault lost {v:.3} | baseline lost {b:.3}");
            }
        }
        other => eprintln!("unknown --fig {other}"),
    }
}

fn cmd_analyze(args: &Args) {
    let n = args.get("n", 80usize);
    let k = args.get("k", 32usize);
    let churn_q = args.get("churn-q", 0.01f64);
    let evict = args.get("evict", 0usize);
    let steps = args.get("steps", 512usize);
    let cfg = ctmc::CtmcConfig { n, k, churn_q, evict, ..Default::default() };
    let chain = ctmc::build_chain(&cfg);
    let series = chain.absorb_series(steps);
    println!("CTMC (n={n}, k={k}, q={churn_q}, Y={evict}): P(lost) after T steps");
    for t in [1, 8, 64, steps.min(256), steps] {
        println!("  T={t:>5}: {:.3e}", series[t - 1]);
    }
    println!(
        "object bound over {} chunks: {:.3e}",
        vault::params::N_OUTER,
        chain.object_loss_bound(steps, vault::params::N_OUTER)
    );
    println!(
        "initial-state invalid (exact): {:.3e} | hoeffding: {:.3e}",
        bounds::initial_invalid_prob(100_000, 33_333, n as u64, k as u64),
        bounds::initial_invalid_hoeffding(n as u64, k as u64),
    );
    for phi in [100u64, 1_000, 10_000] {
        println!(
            "targeted bound (O=1e4, K=8, R=2, phi={phi}, mu=8): {:.3e}",
            bounds::targeted_attack_bound(10_000, 8, 2, phi, 8)
        );
    }
}

fn cmd_artifacts(args: &Args) {
    let dir = std::path::PathBuf::from(args.str("dir", "artifacts"));
    let rt = match Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("failed to load artifacts from {dir:?}: {e:#}");
            std::process::exit(1);
        }
    };
    println!("loaded artifacts: encoders {:?}", rt.encoder_variants());
    // Cross-check against the native codec.
    let mut rng = Rng::new(3);
    let mut chunk = vec![0u8; 200_000];
    rng.fill_bytes(&mut chunk);
    let chash = Hash256::of(&chunk);
    let k = vault::params::K_INNER;
    let indices: Vec<u64> = (0..vault::params::R_INNER as u64).collect();
    let native = vault::codec::InnerEncoder::new(chash, &chunk, k);
    let t = Timer::start();
    let frags = rt.encode_chunk(&chash, &chunk, k, &indices).expect("encode");
    println!("artifact encode of {} fragments: {:.1} ms", frags.len(), t.elapsed_ms());
    for f in &frags {
        assert_eq!(*f, native.fragment(f.index), "artifact/native mismatch");
    }
    let t = Timer::start();
    let decoded = rt
        .decode_chunk(&chash, k, &frags[..k])
        .expect("decode")
        .expect("full rank");
    println!("artifact decode: {:.1} ms, intact={}", t.elapsed_ms(), decoded == chunk);
    assert_eq!(decoded, chunk);
    println!("artifacts cross-check OK");
}
