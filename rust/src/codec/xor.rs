//! XOR hot loops — the innermost operation of the GF(2) fountain code.
//!
//! `xor_into` is on the per-fragment encode/decode/repair path; it works
//! u64-wide with an unrolled main loop so the compiler autovectorizes.

/// dst ^= src (lengths must match).
pub fn xor_into(dst: &mut [u8], src: &[u8]) {
    assert_eq!(dst.len(), src.len());
    // u64-wide main loop.
    let n = dst.len() / 8;
    let (d_head, d_tail) = dst.split_at_mut(n * 8);
    let (s_head, s_tail) = src.split_at(n * 8);
    // Unroll by 4 words (32 bytes) — matches one AVX2 lane pair.
    let mut i = 0;
    while i + 32 <= d_head.len() {
        for j in (i..i + 32).step_by(8) {
            let d = u64::from_ne_bytes(d_head[j..j + 8].try_into().unwrap());
            let s = u64::from_ne_bytes(s_head[j..j + 8].try_into().unwrap());
            d_head[j..j + 8].copy_from_slice(&(d ^ s).to_ne_bytes());
        }
        i += 32;
    }
    while i + 8 <= d_head.len() {
        let d = u64::from_ne_bytes(d_head[i..i + 8].try_into().unwrap());
        let s = u64::from_ne_bytes(s_head[i..i + 8].try_into().unwrap());
        d_head[i..i + 8].copy_from_slice(&(d ^ s).to_ne_bytes());
        i += 8;
    }
    for (d, s) in d_tail.iter_mut().zip(s_tail) {
        *d ^= s;
    }
}

/// out = XOR of the rows of `src` selected by `mask` (one bit per row).
/// `src` is a flat row-major [rows × row_len] buffer.
pub fn xor_select(out: &mut [u8], src: &[u8], row_len: usize, mask: impl Iterator<Item = usize>) {
    out.fill(0);
    for row in mask {
        let start = row * row_len;
        xor_into(out, &src[start..start + row_len]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn xor_into_matches_naive() {
        let mut rng = Rng::new(50);
        for len in [0usize, 1, 7, 8, 9, 31, 32, 33, 100, 4096, 4097] {
            let mut a = vec![0u8; len];
            let mut b = vec![0u8; len];
            rng.fill_bytes(&mut a);
            rng.fill_bytes(&mut b);
            let want: Vec<u8> = a.iter().zip(&b).map(|(x, y)| x ^ y).collect();
            xor_into(&mut a, &b);
            assert_eq!(a, want, "len={len}");
        }
    }

    #[test]
    fn xor_into_is_involution() {
        let mut rng = Rng::new(51);
        let mut a = vec![0u8; 1000];
        let b = {
            let mut b = vec![0u8; 1000];
            rng.fill_bytes(&mut b);
            b
        };
        let orig = a.clone();
        xor_into(&mut a, &b);
        xor_into(&mut a, &b);
        assert_eq!(a, orig);
    }

    #[test]
    fn xor_select_basic() {
        let row_len = 16;
        let src: Vec<u8> = (0..4 * row_len).map(|i| i as u8).collect();
        let mut out = vec![0u8; row_len];
        xor_select(&mut out, &src, row_len, [0usize, 2].into_iter());
        for i in 0..row_len {
            assert_eq!(out[i], src[i] ^ src[2 * row_len + i]);
        }
    }
}
