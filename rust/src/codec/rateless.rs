//! The inner rateless code: a random linear fountain over GF(2).
//!
//! This is the VAULT "inner code" (§3.2, §4.2). Every chunk has an
//! *infinite* stream of encoding fragments indexed by `u64`; the
//! coefficient row of fragment `i` is derived deterministically from
//! `(chunk hash, i)` via a SHA-256 DRBG, so every party in the system
//! derives identical symbols without coordination (the paper's
//! "consensus-free repair"). Any `k + ε` fragments with full-rank rows
//! decode; for random GF(2) rows E[ε] ≈ 1.6.
//!
//! Substitution note (DESIGN.md): the paper uses wirehair (structured
//! fountain, ε ≈ 0.02); a dense random fountain has identical protocol-
//! level properties — indexed infinite symbol space, deterministic rows,
//! overhead-ε decode — with a slightly larger ε, which we surface in
//! benches rather than hide.

use crate::crypto::Hash256;
use crate::util::rng::HashDrbg;
use crate::wire::{Decode, Encode, Reader, WireResult, Writer};

use super::xor::xor_into;

/// One encoding fragment of a chunk.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fragment {
    /// Position in the infinite encoding stream.
    pub index: u64,
    /// Length of the original chunk in bytes (for truncation at decode).
    pub chunk_len: u32,
    /// XOR combination of the source blocks selected by the row of
    /// `index`; length = block size of the chunk.
    pub payload: Vec<u8>,
}

impl Encode for Fragment {
    fn encode(&self, w: &mut Writer) {
        w.u64(self.index);
        w.u32(self.chunk_len);
        self.payload.encode(w);
    }
}

impl Decode for Fragment {
    fn decode(r: &mut Reader<'_>) -> WireResult<Self> {
        Ok(Fragment {
            index: u64::decode(r)?,
            chunk_len: u32::decode(r)?,
            payload: Vec::<u8>::decode(r)?,
        })
    }
}

/// Deterministic coefficient row for fragment `index` of chunk `chash`:
/// `k` bits, never all-zero.
pub fn coeff_row(chash: &Hash256, index: u64, k: usize) -> Vec<bool> {
    debug_assert!(k > 0 && k <= 1024);
    for attempt in 0u32.. {
        let mut seed = Vec::with_capacity(32 + 8 + 4 + 16);
        seed.extend_from_slice(b"vault-inner-row-v1");
        seed.extend_from_slice(&chash.0);
        seed.extend_from_slice(&index.to_le_bytes());
        seed.extend_from_slice(&attempt.to_le_bytes());
        let mut drbg = HashDrbg::new(&seed);
        let mut bytes = vec![0u8; k.div_ceil(8)];
        drbg.fill(&mut bytes);
        let bits: Vec<bool> = (0..k).map(|i| (bytes[i / 8] >> (i % 8)) & 1 == 1).collect();
        if bits.iter().any(|&b| b) {
            return bits;
        }
    }
    unreachable!()
}

/// Bit-packed u32 words of a coefficient row — the layout the AOT decode
/// artifact consumes (`rlf_decode` input `coeff_bits`).
pub fn coeff_row_packed(chash: &Hash256, index: u64, k: usize) -> Vec<u32> {
    let bits = coeff_row(chash, index, k);
    let mut out = vec![0u32; k.div_ceil(32)];
    for (i, b) in bits.iter().enumerate() {
        if *b {
            out[i / 32] |= 1 << (i % 32);
        }
    }
    out
}

/// Block size for a chunk of `len` bytes split into `k` source blocks.
pub fn block_size(len: usize, k: usize) -> usize {
    len.div_ceil(k).max(1)
}

/// Inner-code encoder: holds the chunk's source blocks and materializes
/// any fragment index on demand.
pub struct InnerEncoder {
    chash: Hash256,
    k: usize,
    chunk_len: u32,
    block_size: usize,
    /// Padded source blocks, row-major `k × block_size`.
    blocks: Vec<u8>,
}

impl InnerEncoder {
    pub fn new(chash: Hash256, chunk: &[u8], k: usize) -> Self {
        assert!(k >= 1);
        let bs = block_size(chunk.len(), k);
        let mut blocks = vec![0u8; k * bs];
        blocks[..chunk.len()].copy_from_slice(chunk);
        InnerEncoder { chash, k, chunk_len: chunk.len() as u32, block_size: bs, blocks }
    }

    pub fn k(&self) -> usize {
        self.k
    }
    pub fn block_size(&self) -> usize {
        self.block_size
    }
    pub fn blocks(&self) -> &[u8] {
        &self.blocks
    }
    pub fn chunk_len(&self) -> u32 {
        self.chunk_len
    }

    /// Materialize fragment `index` (native XOR path; the runtime module
    /// offers an artifact-backed batch path with identical output).
    pub fn fragment(&self, index: u64) -> Fragment {
        let row = coeff_row(&self.chash, index, self.k);
        let mut payload = vec![0u8; self.block_size];
        for (i, &sel) in row.iter().enumerate() {
            if sel {
                xor_into(&mut payload, &self.blocks[i * self.block_size..(i + 1) * self.block_size]);
            }
        }
        Fragment { index, chunk_len: self.chunk_len, payload }
    }

    /// Batch fragment generation (used by STORE: indices 0..r or random).
    pub fn fragments(&self, indices: &[u64]) -> Vec<Fragment> {
        indices.iter().map(|&i| self.fragment(i)).collect()
    }
}

/// Incremental inner-code decoder: feed fragments in any order; decodes
/// as soon as the received rows span GF(2)^k.
///
/// Maintains a row-reduced basis: each accepted fragment is eliminated
/// against existing pivots; redundant (dependent) fragments are
/// discarded. O(k) row ops per fragment, O(k²) total.
pub struct InnerDecoder {
    chash: Hash256,
    k: usize,
    block_size: usize,
    chunk_len: Option<u32>,
    /// pivot[c] = Some(row index in `rows` whose leading column is c).
    pivot: Vec<Option<usize>>,
    /// Reduced coefficient rows (bit vectors) and payloads.
    rows: Vec<(Vec<bool>, Vec<u8>)>,
}

impl InnerDecoder {
    pub fn new(chash: Hash256, k: usize) -> Self {
        InnerDecoder {
            chash,
            k,
            block_size: 0,
            chunk_len: None,
            pivot: vec![None; k],
            rows: Vec::with_capacity(k),
        }
    }

    pub fn rank(&self) -> usize {
        self.rows.len()
    }

    pub fn is_complete(&self) -> bool {
        self.rows.len() == self.k
    }

    /// Feed one fragment. Returns `true` if it increased the rank.
    pub fn push(&mut self, frag: &Fragment) -> bool {
        if self.is_complete() {
            return false;
        }
        match self.chunk_len {
            None => {
                self.chunk_len = Some(frag.chunk_len);
                self.block_size = frag.payload.len();
            }
            Some(len) => {
                // Inconsistent metadata ⇒ corrupt/Byzantine fragment.
                if len != frag.chunk_len || frag.payload.len() != self.block_size {
                    return false;
                }
            }
        }
        let mut row = coeff_row(&self.chash, frag.index, self.k);
        let mut payload = frag.payload.clone();
        // Eliminate against existing pivots.
        for c in 0..self.k {
            if !row[c] {
                continue;
            }
            if let Some(pr) = self.pivot[c] {
                let (prow, ppay) = &self.rows[pr];
                let prow = prow.clone();
                xor_into(&mut payload, &ppay.clone());
                for (b, pb) in row.iter_mut().zip(prow.iter()) {
                    *b ^= pb;
                }
            }
        }
        // Find the new leading column.
        let lead = match row.iter().position(|&b| b) {
            Some(c) => c,
            None => return false, // linearly dependent
        };
        // Back-substitute into existing rows that have this column set.
        for r in 0..self.rows.len() {
            if self.rows[r].0[lead] {
                let payload_clone = payload.clone();
                let row_clone = row.clone();
                let (erow, epay) = &mut self.rows[r];
                xor_into(epay, &payload_clone);
                for (b, nb) in erow.iter_mut().zip(row_clone.iter()) {
                    *b ^= nb;
                }
            }
        }
        self.pivot[lead] = Some(self.rows.len());
        self.rows.push((row, payload));
        true
    }

    /// Recover the chunk once complete.
    pub fn recover(&self) -> Option<Vec<u8>> {
        if !self.is_complete() {
            return None;
        }
        let len = self.chunk_len? as usize;
        let mut out = vec![0u8; self.k * self.block_size];
        for c in 0..self.k {
            let r = self.pivot[c]?;
            let (row, payload) = &self.rows[r];
            // After full reduction each pivot row must be the unit vector e_c.
            debug_assert!(row.iter().enumerate().all(|(i, &b)| b == (i == c)));
            out[c * self.block_size..(c + 1) * self.block_size].copy_from_slice(payload);
        }
        out.truncate(len);
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn chash(tag: u8) -> Hash256 {
        Hash256::of(&[tag])
    }

    fn roundtrip(seed: u64, k: usize, len: usize, extra: u64) -> usize {
        let mut rng = Rng::new(seed);
        let mut chunk = vec![0u8; len];
        rng.fill_bytes(&mut chunk);
        let h = chash(seed as u8);
        let enc = InnerEncoder::new(h, &chunk, k);
        let mut dec = InnerDecoder::new(h, k);
        let mut used = 0;
        for i in 0..(k as u64 + extra + 64) {
            let f = enc.fragment(i);
            used += 1;
            dec.push(&f);
            if dec.is_complete() {
                break;
            }
        }
        assert!(dec.is_complete(), "failed to decode k={k} len={len}");
        assert_eq!(dec.recover().unwrap(), chunk);
        used
    }

    #[test]
    fn encode_decode_roundtrip_various_sizes() {
        for (seed, k, len) in [
            (1u64, 32usize, 10_000usize),
            (2, 32, 1),
            (3, 32, 31),      // smaller than k
            (4, 16, 4096),
            (5, 64, 100_000),
            (6, 1, 500),
            (7, 8, 8),
        ] {
            roundtrip(seed, k, len, 8);
        }
    }

    #[test]
    fn decode_from_random_subset() {
        // Any sufficiently large random subset of the stream decodes.
        let mut rng = Rng::new(100);
        let k = 32;
        let mut chunk = vec![0u8; 5000];
        rng.fill_bytes(&mut chunk);
        let h = chash(9);
        let enc = InnerEncoder::new(h, &chunk, k);
        for trial in 0..5 {
            let mut dec = InnerDecoder::new(h, k);
            // random indices from a large space
            let mut n = 0;
            while !dec.is_complete() {
                let idx = rng.next_u64() % 1_000_000;
                dec.push(&enc.fragment(idx));
                n += 1;
                assert!(n < 200, "trial {trial}: too many fragments");
            }
            assert_eq!(dec.recover().unwrap(), chunk);
        }
    }

    #[test]
    fn overhead_epsilon_is_small() {
        // E[extra fragments beyond k] ≈ 1.6 for a random GF(2) fountain.
        let mut total_extra = 0usize;
        let trials = 30;
        for s in 0..trials {
            let used = roundtrip(200 + s, 32, 2048, 32);
            total_extra += used - 32;
        }
        let mean = total_extra as f64 / trials as f64;
        assert!(mean < 4.0, "mean overhead {mean}");
    }

    #[test]
    fn dependent_fragments_rejected() {
        let h = chash(1);
        let enc = InnerEncoder::new(h, &[1, 2, 3, 4, 5, 6, 7, 8], 4);
        let mut dec = InnerDecoder::new(h, 4);
        let f = enc.fragment(0);
        assert!(dec.push(&f));
        assert!(!dec.push(&f)); // same fragment is dependent
        assert_eq!(dec.rank(), 1);
    }

    #[test]
    fn corrupt_metadata_rejected() {
        let h = chash(2);
        let enc = InnerEncoder::new(h, &[0u8; 100], 4);
        let mut dec = InnerDecoder::new(h, 4);
        dec.push(&enc.fragment(0));
        let mut bad = enc.fragment(1);
        bad.chunk_len = 999; // lie about chunk length
        assert!(!dec.push(&bad));
    }

    #[test]
    fn coeff_rows_deterministic_and_distinct() {
        let h = chash(3);
        let a = coeff_row(&h, 42, 32);
        let b = coeff_row(&h, 42, 32);
        assert_eq!(a, b);
        let c = coeff_row(&h, 43, 32);
        assert_ne!(a, c);
        let other = coeff_row(&chash(4), 42, 32);
        assert_ne!(a, other);
        assert!(a.iter().any(|&x| x), "rows never all-zero");
    }

    #[test]
    fn packed_row_matches_bits() {
        let h = chash(5);
        for idx in 0..10u64 {
            let bits = coeff_row(&h, idx, 40);
            let packed = coeff_row_packed(&h, idx, 40);
            for (i, &b) in bits.iter().enumerate() {
                assert_eq!((packed[i / 32] >> (i % 32)) & 1 == 1, b);
            }
        }
    }

    #[test]
    fn fragment_wire_roundtrip() {
        use crate::wire::{Decode, Encode};
        let h = chash(6);
        let enc = InnerEncoder::new(h, b"wire test data", 4);
        let f = enc.fragment(77);
        let got = Fragment::from_bytes(&f.to_bytes()).unwrap();
        assert_eq!(got, f);
    }
}
