//! The inner rateless code: a random linear fountain over GF(2).
//!
//! This is the VAULT "inner code" (§3.2, §4.2). Every chunk has an
//! *infinite* stream of encoding fragments indexed by `u64`; the
//! coefficient row of fragment `i` is derived deterministically from
//! `(chunk hash, i)` via a SHA-256 DRBG, so every party in the system
//! derives identical symbols without coordination (the paper's
//! "consensus-free repair"). Any `k + ε` fragments with full-rank rows
//! decode; for random GF(2) rows E[ε] ≈ 1.6.
//!
//! Substitution note (DESIGN.md): the paper uses wirehair (structured
//! fountain, ε ≈ 0.02); a dense random fountain has identical protocol-
//! level properties — indexed infinite symbol space, deterministic rows,
//! overhead-ε decode — with a slightly larger ε, which we surface in
//! benches rather than hide.

use crate::crypto::Hash256;
use crate::util::rng::HashDrbg;
use crate::wire::{Decode, Encode, Reader, WireResult, Writer};

use super::xor::xor_into;

/// One encoding fragment of a chunk.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fragment {
    /// Position in the infinite encoding stream.
    pub index: u64,
    /// Length of the original chunk in bytes (for truncation at decode).
    pub chunk_len: u32,
    /// XOR combination of the source blocks selected by the row of
    /// `index`; length = block size of the chunk.
    pub payload: Vec<u8>,
}

impl Encode for Fragment {
    fn encode(&self, w: &mut Writer) {
        w.u64(self.index);
        w.u32(self.chunk_len);
        self.payload.encode(w);
    }
}

impl Decode for Fragment {
    fn decode(r: &mut Reader<'_>) -> WireResult<Self> {
        Ok(Fragment {
            index: u64::decode(r)?,
            chunk_len: u32::decode(r)?,
            payload: Vec::<u8>::decode(r)?,
        })
    }
}

/// Upper bound on the inner-code dimension; rows fit in
/// `MAX_K / 64 = 16` packed words, so per-row scratch lives on the stack.
pub const MAX_K: usize = 1024;

/// Packed words per coefficient row of dimension `k`.
#[inline]
pub fn row_words(k: usize) -> usize {
    k.div_ceil(64)
}

/// Bit `i` of a packed coefficient row.
#[inline]
pub fn row_bit(words: &[u64], i: usize) -> bool {
    (words[i / 64] >> (i % 64)) & 1 == 1
}

/// Fixed-layout DRBG seed for row derivation:
/// `"vault-inner-row-v1" ‖ chash ‖ index ‖ attempt` (18+32+8+4 bytes).
/// Built once per derivation; the retry loop patches only the trailing
/// attempt-counter bytes in place.
const ROW_SEED_LEN: usize = 18 + 32 + 8 + 4;

/// Derive the coefficient row of fragment `index` into `out`
/// (`row_words(k)` packed words, little-endian bit order: bit `i` of the
/// row is bit `i % 64` of word `i / 64`). Never all-zero. Performs no
/// heap allocation — this is the decoder's steady-state path.
pub fn coeff_row_into(chash: &Hash256, index: u64, k: usize, out: &mut [u64]) {
    assert!(k > 0 && k <= MAX_K, "inner-code dimension {k} out of range");
    debug_assert_eq!(out.len(), row_words(k));
    let mut seed = [0u8; ROW_SEED_LEN];
    seed[..18].copy_from_slice(b"vault-inner-row-v1");
    seed[18..50].copy_from_slice(&chash.0);
    seed[50..58].copy_from_slice(&index.to_le_bytes());
    for attempt in 0u32.. {
        seed[58..62].copy_from_slice(&attempt.to_le_bytes());
        let mut drbg = HashDrbg::new(&seed);
        let mut bytes = [0u8; MAX_K / 8];
        drbg.fill(&mut bytes[..k.div_ceil(8)]);
        for (w, b8) in out.iter_mut().zip(bytes.chunks_exact(8)) {
            *w = u64::from_le_bytes(b8.try_into().unwrap());
        }
        // Mask the partial tail word so bits ≥ k are always clear.
        if k % 64 != 0 {
            out[k / 64] &= (1u64 << (k % 64)) - 1;
        }
        if out.iter().any(|&w| w != 0) {
            return;
        }
    }
    unreachable!()
}

/// Deterministic coefficient row for fragment `index` of chunk `chash`:
/// `k` bits packed into `u64` words, never all-zero. Allocating wrapper
/// around [`coeff_row_into`]; bit `i` is read with [`row_bit`].
pub fn coeff_row(chash: &Hash256, index: u64, k: usize) -> Vec<u64> {
    let mut out = vec![0u64; row_words(k)];
    coeff_row_into(chash, index, k, &mut out);
    out
}

/// Bit-packed u32 words of a coefficient row — the layout the AOT decode
/// artifact consumes (`rlf_decode` input `coeff_bits`). Splits the
/// native u64 words directly (no bool round-trip).
pub fn coeff_row_packed(chash: &Hash256, index: u64, k: usize) -> Vec<u32> {
    let words = coeff_row(chash, index, k);
    let mut out = vec![0u32; k.div_ceil(32)];
    for (i, o) in out.iter_mut().enumerate() {
        let w = words[i / 2];
        *o = if i % 2 == 0 { w as u32 } else { (w >> 32) as u32 };
    }
    out
}

/// Block size for a chunk of `len` bytes split into `k` source blocks.
pub fn block_size(len: usize, k: usize) -> usize {
    len.div_ceil(k).max(1)
}

/// Inner-code encoder: holds the chunk's source blocks and materializes
/// any fragment index on demand.
pub struct InnerEncoder {
    chash: Hash256,
    k: usize,
    chunk_len: u32,
    block_size: usize,
    /// Padded source blocks, row-major `k × block_size`.
    blocks: Vec<u8>,
}

impl InnerEncoder {
    pub fn new(chash: Hash256, chunk: &[u8], k: usize) -> Self {
        assert!(k >= 1 && k <= MAX_K);
        let bs = block_size(chunk.len(), k);
        let mut blocks = vec![0u8; k * bs];
        blocks[..chunk.len()].copy_from_slice(chunk);
        InnerEncoder { chash, k, chunk_len: chunk.len() as u32, block_size: bs, blocks }
    }

    pub fn k(&self) -> usize {
        self.k
    }
    pub fn block_size(&self) -> usize {
        self.block_size
    }
    pub fn blocks(&self) -> &[u8] {
        &self.blocks
    }
    pub fn chunk_len(&self) -> u32 {
        self.chunk_len
    }

    /// XOR the source blocks selected by fragment `index`'s row into
    /// `payload` (must be zeroed, `block_size` long). Allocation-free:
    /// the row lives in a stack array and set bits are walked word-wise.
    fn encode_payload_into(&self, index: u64, payload: &mut [u8]) {
        debug_assert_eq!(payload.len(), self.block_size);
        let mut row = [0u64; MAX_K / 64];
        let words = row_words(self.k);
        coeff_row_into(&self.chash, index, self.k, &mut row[..words]);
        for (wi, &w) in row[..words].iter().enumerate() {
            let mut bits = w;
            while bits != 0 {
                let i = wi * 64 + bits.trailing_zeros() as usize;
                xor_into(payload, &self.blocks[i * self.block_size..(i + 1) * self.block_size]);
                bits &= bits - 1;
            }
        }
    }

    /// Materialize fragment `index` (native XOR path; the runtime module
    /// offers an artifact-backed batch path with identical output).
    pub fn fragment(&self, index: u64) -> Fragment {
        let mut payload = vec![0u8; self.block_size];
        self.encode_payload_into(index, &mut payload);
        Fragment { index, chunk_len: self.chunk_len, payload }
    }

    /// Batch fragment generation (used by STORE: indices 0..r or random).
    pub fn fragments(&self, indices: &[u64]) -> Vec<Fragment> {
        let mut out = Vec::new();
        self.fragments_into(indices, &mut out);
        out
    }

    /// Batch fragment generation into a caller-provided arena. Existing
    /// `Fragment` slots (and their payload buffers) in `out` are reused,
    /// so repeated calls with same-shape batches are allocation-free
    /// after the first — the repair loop's steady state.
    pub fn fragments_into(&self, indices: &[u64], out: &mut Vec<Fragment>) {
        out.truncate(indices.len());
        while out.len() < indices.len() {
            out.push(Fragment {
                index: 0,
                chunk_len: self.chunk_len,
                payload: Vec::with_capacity(self.block_size),
            });
        }
        for (slot, &index) in out.iter_mut().zip(indices) {
            slot.index = index;
            slot.chunk_len = self.chunk_len;
            slot.payload.clear();
            slot.payload.resize(self.block_size, 0);
            self.encode_payload_into(index, &mut slot.payload);
        }
    }
}

/// Incremental inner-code decoder: feed fragments in any order; decodes
/// as soon as the received rows span GF(2)^k.
///
/// Maintains a row-reduced basis: each accepted fragment is eliminated
/// against existing pivots word-wise; redundant (dependent) fragments
/// are discarded. O(k) row ops per fragment, O(k²) total.
///
/// Storage is two flat arenas (coefficient words and payload bytes) plus
/// persistent scratch buffers for the incoming row, so steady-state
/// [`push`](Self::push) — everything after the first fragment sizes the
/// payload arena — performs zero heap allocations.
pub struct InnerDecoder {
    chash: Hash256,
    k: usize,
    /// Packed words per coefficient row.
    words: usize,
    block_size: usize,
    chunk_len: Option<u32>,
    /// Accepted (pivot) rows so far.
    nrows: usize,
    /// pivot[c] = Some(arena row whose leading column is c).
    pivot: Vec<Option<usize>>,
    /// Reduced coefficient rows, row-major `k × words`.
    coeff: Vec<u64>,
    /// Reduced payload rows, row-major `k × block_size` (sized on first push).
    payloads: Vec<u8>,
    /// Scratch for the incoming row / payload being eliminated.
    scratch_row: Vec<u64>,
    scratch_pay: Vec<u8>,
}

impl InnerDecoder {
    pub fn new(chash: Hash256, k: usize) -> Self {
        assert!(k >= 1 && k <= MAX_K);
        let words = row_words(k);
        InnerDecoder {
            chash,
            k,
            words,
            block_size: 0,
            chunk_len: None,
            nrows: 0,
            pivot: vec![None; k],
            coeff: vec![0u64; k * words],
            payloads: Vec::new(),
            scratch_row: vec![0u64; words],
            scratch_pay: Vec::new(),
        }
    }

    pub fn rank(&self) -> usize {
        self.nrows
    }

    pub fn is_complete(&self) -> bool {
        self.nrows == self.k
    }

    /// Feed one fragment. Returns `true` if it increased the rank.
    pub fn push(&mut self, frag: &Fragment) -> bool {
        if self.is_complete() {
            return false;
        }
        match self.chunk_len {
            None => {
                // First fragment fixes the geometry and sizes the payload
                // arena — the only allocating push.
                self.chunk_len = Some(frag.chunk_len);
                self.block_size = frag.payload.len();
                self.payloads.resize(self.k * self.block_size, 0);
                self.scratch_pay.resize(self.block_size, 0);
            }
            Some(len) => {
                // Inconsistent metadata ⇒ corrupt/Byzantine fragment.
                if len != frag.chunk_len || frag.payload.len() != self.block_size {
                    return false;
                }
            }
        }
        // Move the scratch buffers out so the elimination below can
        // borrow the arenas immutably alongside them (no clones, no
        // allocation: `take` swaps in empty Vecs).
        let mut row = std::mem::take(&mut self.scratch_row);
        let mut pay = std::mem::take(&mut self.scratch_pay);
        coeff_row_into(&self.chash, frag.index, self.k, &mut row);
        pay.copy_from_slice(&frag.payload);

        // Eliminate against existing pivots. Pivot rows are reduced —
        // row `pivot[c]` has leading column c — so scanning columns in
        // ascending order only ever toggles bits ≥ c.
        for c in 0..self.k {
            if !row_bit(&row, c) {
                continue;
            }
            if let Some(pr) = self.pivot[c] {
                let prow = &self.coeff[pr * self.words..(pr + 1) * self.words];
                for (w, pw) in row.iter_mut().zip(prow) {
                    *w ^= pw;
                }
                let ppay = &self.payloads[pr * self.block_size..(pr + 1) * self.block_size];
                xor_into(&mut pay, ppay);
            }
        }
        // Find the new leading column word-wise.
        let lead = row
            .iter()
            .enumerate()
            .find(|&(_, &w)| w != 0)
            .map(|(wi, &w)| wi * 64 + w.trailing_zeros() as usize);
        let accepted = match lead {
            None => false, // linearly dependent
            Some(lead) => {
                // Back-substitute into existing rows that have this column set.
                for r in 0..self.nrows {
                    if !row_bit(&self.coeff[r * self.words..(r + 1) * self.words], lead) {
                        continue;
                    }
                    let erow = &mut self.coeff[r * self.words..(r + 1) * self.words];
                    for (w, nw) in erow.iter_mut().zip(row.iter()) {
                        *w ^= nw;
                    }
                    let epay =
                        &mut self.payloads[r * self.block_size..(r + 1) * self.block_size];
                    xor_into(epay, &pay);
                }
                // Install the new pivot row into the arenas.
                let n = self.nrows;
                self.coeff[n * self.words..(n + 1) * self.words].copy_from_slice(&row);
                self.payloads[n * self.block_size..(n + 1) * self.block_size]
                    .copy_from_slice(&pay);
                self.pivot[lead] = Some(n);
                self.nrows += 1;
                true
            }
        };
        self.scratch_row = row;
        self.scratch_pay = pay;
        accepted
    }

    /// Recover the chunk once complete.
    pub fn recover(&self) -> Option<Vec<u8>> {
        if !self.is_complete() {
            return None;
        }
        let len = self.chunk_len? as usize;
        let mut out = vec![0u8; self.k * self.block_size];
        for c in 0..self.k {
            let r = self.pivot[c]?;
            let row = &self.coeff[r * self.words..(r + 1) * self.words];
            // After full reduction each pivot row must be the unit vector e_c.
            debug_assert!((0..self.k).all(|i| row_bit(row, i) == (i == c)));
            out[c * self.block_size..(c + 1) * self.block_size]
                .copy_from_slice(&self.payloads[r * self.block_size..(r + 1) * self.block_size]);
        }
        out.truncate(len);
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn chash(tag: u8) -> Hash256 {
        Hash256::of(&[tag])
    }

    fn roundtrip(seed: u64, k: usize, len: usize, extra: u64) -> usize {
        let mut rng = Rng::new(seed);
        let mut chunk = vec![0u8; len];
        rng.fill_bytes(&mut chunk);
        let h = chash(seed as u8);
        let enc = InnerEncoder::new(h, &chunk, k);
        let mut dec = InnerDecoder::new(h, k);
        let mut used = 0;
        for i in 0..(k as u64 + extra + 64) {
            let f = enc.fragment(i);
            used += 1;
            dec.push(&f);
            if dec.is_complete() {
                break;
            }
        }
        assert!(dec.is_complete(), "failed to decode k={k} len={len}");
        assert_eq!(dec.recover().unwrap(), chunk);
        used
    }

    #[test]
    fn encode_decode_roundtrip_various_sizes() {
        for (seed, k, len) in [
            (1u64, 32usize, 10_000usize),
            (2, 32, 1),
            (3, 32, 31),      // smaller than k
            (4, 16, 4096),
            (5, 64, 100_000),
            (6, 1, 500),
            (7, 8, 8),
        ] {
            roundtrip(seed, k, len, 8);
        }
    }

    #[test]
    fn decode_from_random_subset() {
        // Any sufficiently large random subset of the stream decodes.
        let mut rng = Rng::new(100);
        let k = 32;
        let mut chunk = vec![0u8; 5000];
        rng.fill_bytes(&mut chunk);
        let h = chash(9);
        let enc = InnerEncoder::new(h, &chunk, k);
        for trial in 0..5 {
            let mut dec = InnerDecoder::new(h, k);
            // random indices from a large space
            let mut n = 0;
            while !dec.is_complete() {
                let idx = rng.next_u64() % 1_000_000;
                dec.push(&enc.fragment(idx));
                n += 1;
                assert!(n < 200, "trial {trial}: too many fragments");
            }
            assert_eq!(dec.recover().unwrap(), chunk);
        }
    }

    #[test]
    fn overhead_epsilon_is_small() {
        // E[extra fragments beyond k] ≈ 1.6 for a random GF(2) fountain.
        let mut total_extra = 0usize;
        let trials = 30;
        for s in 0..trials {
            let used = roundtrip(200 + s, 32, 2048, 32);
            total_extra += used - 32;
        }
        let mean = total_extra as f64 / trials as f64;
        assert!(mean < 4.0, "mean overhead {mean}");
    }

    #[test]
    fn dependent_fragments_rejected() {
        let h = chash(1);
        let enc = InnerEncoder::new(h, &[1, 2, 3, 4, 5, 6, 7, 8], 4);
        let mut dec = InnerDecoder::new(h, 4);
        let f = enc.fragment(0);
        assert!(dec.push(&f));
        assert!(!dec.push(&f)); // same fragment is dependent
        assert_eq!(dec.rank(), 1);
    }

    #[test]
    fn corrupt_metadata_rejected() {
        let h = chash(2);
        let enc = InnerEncoder::new(h, &[0u8; 100], 4);
        let mut dec = InnerDecoder::new(h, 4);
        dec.push(&enc.fragment(0));
        let mut bad = enc.fragment(1);
        bad.chunk_len = 999; // lie about chunk length
        assert!(!dec.push(&bad));
    }

    #[test]
    fn coeff_rows_deterministic_and_distinct() {
        let h = chash(3);
        let a = coeff_row(&h, 42, 32);
        let b = coeff_row(&h, 42, 32);
        assert_eq!(a, b);
        let c = coeff_row(&h, 43, 32);
        assert_ne!(a, c);
        let other = coeff_row(&chash(4), 42, 32);
        assert_ne!(a, other);
        assert!(a.iter().any(|&x| x != 0), "rows never all-zero");
    }

    #[test]
    fn packed_row_matches_bits() {
        let h = chash(5);
        for k in [40usize, 64, 65, 100] {
            for idx in 0..10u64 {
                let words = coeff_row(&h, idx, k);
                assert_eq!(words.len(), row_words(k));
                let packed = coeff_row_packed(&h, idx, k);
                for i in 0..k {
                    assert_eq!((packed[i / 32] >> (i % 32)) & 1 == 1, row_bit(&words, i));
                }
                // Bits beyond k are always masked off.
                for i in k..words.len() * 64 {
                    assert!(!row_bit(&words, i), "k={k} stray bit {i}");
                }
            }
        }
    }

    #[test]
    fn fragments_into_reuses_slots_and_matches() {
        let mut rng = Rng::new(91);
        let mut chunk = vec![0u8; 4096];
        rng.fill_bytes(&mut chunk);
        let h = chash(8);
        let enc = InnerEncoder::new(h, &chunk, 16);
        let indices: Vec<u64> = (100..140).collect();
        let mut arena = Vec::new();
        enc.fragments_into(&indices, &mut arena);
        assert_eq!(arena.len(), indices.len());
        for (f, &i) in arena.iter().zip(&indices) {
            assert_eq!(*f, enc.fragment(i));
        }
        // Second batch into the same arena: same results, reused slots.
        let indices2: Vec<u64> = (7..27).collect();
        enc.fragments_into(&indices2, &mut arena);
        assert_eq!(arena.len(), indices2.len());
        for (f, &i) in arena.iter().zip(&indices2) {
            assert_eq!(*f, enc.fragment(i));
        }
    }

    #[test]
    fn fragment_wire_roundtrip() {
        use crate::wire::{Decode, Encode};
        let h = chash(6);
        let enc = InnerEncoder::new(h, b"wire test data", 4);
        let f = enc.fragment(77);
        let got = Fragment::from_bytes(&f.to_bytes()).unwrap();
        assert_eq!(got, f);
    }
}
