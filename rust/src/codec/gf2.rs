//! Bit-packed GF(2) linear algebra — coefficient-matrix side of the
//! inner fountain code (the payload side lives in [`super::xor`]).

/// Dense bit matrix, row-major, 64-bit word packed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitMatrix {
    pub rows: usize,
    pub cols: usize,
    words_per_row: usize,
    data: Vec<u64>,
}

impl BitMatrix {
    pub fn zero(rows: usize, cols: usize) -> Self {
        let wpr = cols.div_ceil(64);
        BitMatrix { rows, cols, words_per_row: wpr, data: vec![0; rows * wpr] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zero(n, n);
        for i in 0..n {
            m.set(i, i, true);
        }
        m
    }

    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols);
        (self.data[r * self.words_per_row + c / 64] >> (c % 64)) & 1 == 1
    }

    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(r < self.rows && c < self.cols);
        let w = &mut self.data[r * self.words_per_row + c / 64];
        if v {
            *w |= 1 << (c % 64);
        } else {
            *w &= !(1 << (c % 64));
        }
    }

    pub fn row_words(&self, r: usize) -> &[u64] {
        &self.data[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// rows[dst] ^= rows[src]
    pub fn xor_row(&mut self, dst: usize, src: usize) {
        assert_ne!(dst, src);
        let wpr = self.words_per_row;
        let (a, b) = if dst < src {
            let (lo, hi) = self.data.split_at_mut(src * wpr);
            (&mut lo[dst * wpr..(dst + 1) * wpr], &hi[..wpr])
        } else {
            let (lo, hi) = self.data.split_at_mut(dst * wpr);
            (&mut hi[..wpr], &lo[src * wpr..(src + 1) * wpr])
        };
        for (x, y) in a.iter_mut().zip(b) {
            *x ^= y;
        }
    }

    pub fn set_row_from_bits(&mut self, r: usize, bits: &[bool]) {
        assert_eq!(bits.len(), self.cols);
        for (c, &b) in bits.iter().enumerate() {
            self.set(r, c, b);
        }
    }

    pub fn row_is_zero(&self, r: usize) -> bool {
        self.row_words(r).iter().all(|&w| w == 0)
    }

    /// Rank via Gaussian elimination on a copy.
    pub fn rank(&self) -> usize {
        let mut m = self.clone();
        let mut rank = 0;
        let mut pivot_row = 0;
        for col in 0..m.cols {
            // Find a row at or below pivot_row with this column set.
            let mut found = None;
            for r in pivot_row..m.rows {
                if m.get(r, col) {
                    found = Some(r);
                    break;
                }
            }
            let Some(p) = found else { continue };
            if p != pivot_row {
                // Swap rows p and pivot_row.
                let wpr = m.words_per_row;
                for wi in 0..wpr {
                    m.data.swap(p * wpr + wi, pivot_row * wpr + wi);
                }
            }
            for r in 0..m.rows {
                if r != pivot_row && m.get(r, col) {
                    m.xor_row(r, pivot_row);
                }
            }
            rank += 1;
            pivot_row += 1;
            if pivot_row == m.rows {
                break;
            }
        }
        rank
    }

    pub fn is_full_rank(&self) -> bool {
        self.rank() == self.rows.min(self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_matrix(rng: &mut Rng, rows: usize, cols: usize) -> BitMatrix {
        let mut m = BitMatrix::zero(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, rng.chance(0.5));
            }
        }
        m
    }

    #[test]
    fn get_set_roundtrip() {
        let mut m = BitMatrix::zero(3, 130);
        m.set(0, 0, true);
        m.set(2, 129, true);
        m.set(1, 64, true);
        assert!(m.get(0, 0));
        assert!(m.get(2, 129));
        assert!(m.get(1, 64));
        assert!(!m.get(0, 1));
        m.set(0, 0, false);
        assert!(!m.get(0, 0));
    }

    #[test]
    fn identity_full_rank() {
        for n in [1, 7, 64, 65, 100] {
            assert_eq!(BitMatrix::identity(n).rank(), n);
        }
    }

    #[test]
    fn zero_rank_zero() {
        assert_eq!(BitMatrix::zero(5, 5).rank(), 0);
    }

    #[test]
    fn duplicate_rows_reduce_rank() {
        let mut rng = Rng::new(60);
        let mut m = random_matrix(&mut rng, 8, 8);
        // copy row 0 into row 7
        for c in 0..8 {
            let v = m.get(0, c);
            m.set(7, c, v);
        }
        assert!(m.rank() < 8);
    }

    #[test]
    fn xor_row_changes_and_restores() {
        let mut rng = Rng::new(61);
        let mut m = random_matrix(&mut rng, 4, 100);
        let orig = m.clone();
        m.xor_row(1, 3);
        m.xor_row(1, 3);
        assert_eq!(m, orig);
    }

    #[test]
    fn random_square_rank_statistics() {
        // P(full rank) for random k x k GF(2) ~ 0.2887 (k >= 10). Check
        // the observed rate is in a plausible band.
        let mut rng = Rng::new(62);
        let trials = 400;
        let mut full = 0;
        for _ in 0..trials {
            if random_matrix(&mut rng, 16, 16).is_full_rank() {
                full += 1;
            }
        }
        let frac = full as f64 / trials as f64;
        assert!((0.20..0.38).contains(&frac), "frac={frac}");
    }

    #[test]
    fn rank_of_rectangular() {
        let mut rng = Rng::new(63);
        // With 8 extra random rows, rank k is overwhelmingly likely.
        let m = random_matrix(&mut rng, 40, 32);
        assert_eq!(m.rank(), 32);
    }
}
