//! Reference implementations of the coding kernels — the pre-overhaul
//! per-byte / per-bool code paths, kept verbatim so that
//!
//! 1. property tests (`tests/codec_equivalence.rs`) can assert the
//!    optimized word-wise/table-driven kernels are **byte-identical**, and
//! 2. `benches/perf_hotpath.rs` and `vault bench-codec` can measure
//!    before/after speedups on the same machine in the same run.
//!
//! Nothing in the protocol calls this module; it is test/bench substrate
//! only and intentionally mirrors the old structure (per-byte table
//! lookups, `Vec<bool>` rows, per-push row/payload clones).

use crate::crypto::Hash256;
use crate::util::rng::HashDrbg;

use super::xor::xor_into;
use super::{gf256, outer, rateless};

/// Scalar `dst += c * src` over GF(256): per-byte log/exp lookups with a
/// zero-byte branch — the pre-change `addmul_slice` hot loop.
pub fn addmul_slice_ref(dst: &mut [u8], src: &[u8], c: u8) {
    assert_eq!(dst.len(), src.len());
    if c == 0 {
        return;
    }
    if c == 1 {
        xor_into(dst, src);
        return;
    }
    for (d, &s) in dst.iter_mut().zip(src) {
        if s != 0 {
            *d ^= gf256::mul(c, s);
        }
    }
}

/// Scalar in-place scaling by `c` — the pre-change `scale_slice`.
pub fn scale_slice_ref(data: &mut [u8], c: u8) {
    if c == 1 {
        return;
    }
    if c == 0 {
        data.fill(0);
        return;
    }
    for d in data.iter_mut() {
        if *d != 0 {
            *d = gf256::mul(c, *d);
        }
    }
}

/// Pre-change coefficient-row derivation: per-attempt seed Vec, byte
/// buffer, and `Vec<bool>` expansion. Bit `i` equals
/// [`rateless::row_bit`] of the packed row.
pub fn coeff_row_bools(chash: &Hash256, index: u64, k: usize) -> Vec<bool> {
    debug_assert!(k > 0 && k <= rateless::MAX_K);
    for attempt in 0u32.. {
        let mut seed = Vec::with_capacity(32 + 8 + 4 + 16);
        seed.extend_from_slice(b"vault-inner-row-v1");
        seed.extend_from_slice(&chash.0);
        seed.extend_from_slice(&index.to_le_bytes());
        seed.extend_from_slice(&attempt.to_le_bytes());
        let mut drbg = HashDrbg::new(&seed);
        let mut bytes = vec![0u8; k.div_ceil(8)];
        drbg.fill(&mut bytes);
        let bits: Vec<bool> = (0..k).map(|i| (bytes[i / 8] >> (i % 8)) & 1 == 1).collect();
        if bits.iter().any(|&b| b) {
            return bits;
        }
    }
    unreachable!()
}

/// Pre-change inner-code decoder: `Vec<bool>` rows, per-push clones of
/// every pivot row and payload touched.
pub struct InnerDecoderRef {
    chash: Hash256,
    k: usize,
    block_size: usize,
    chunk_len: Option<u32>,
    pivot: Vec<Option<usize>>,
    rows: Vec<(Vec<bool>, Vec<u8>)>,
}

impl InnerDecoderRef {
    pub fn new(chash: Hash256, k: usize) -> Self {
        InnerDecoderRef {
            chash,
            k,
            block_size: 0,
            chunk_len: None,
            pivot: vec![None; k],
            rows: Vec::with_capacity(k),
        }
    }

    pub fn rank(&self) -> usize {
        self.rows.len()
    }

    pub fn is_complete(&self) -> bool {
        self.rows.len() == self.k
    }

    /// Feed one fragment. Returns `true` if it increased the rank.
    pub fn push(&mut self, frag: &rateless::Fragment) -> bool {
        if self.is_complete() {
            return false;
        }
        match self.chunk_len {
            None => {
                self.chunk_len = Some(frag.chunk_len);
                self.block_size = frag.payload.len();
            }
            Some(len) => {
                if len != frag.chunk_len || frag.payload.len() != self.block_size {
                    return false;
                }
            }
        }
        let mut row = coeff_row_bools(&self.chash, frag.index, self.k);
        let mut payload = frag.payload.clone();
        for c in 0..self.k {
            if !row[c] {
                continue;
            }
            if let Some(pr) = self.pivot[c] {
                let (prow, ppay) = &self.rows[pr];
                let prow = prow.clone();
                xor_into(&mut payload, &ppay.clone());
                for (b, pb) in row.iter_mut().zip(prow.iter()) {
                    *b ^= pb;
                }
            }
        }
        let lead = match row.iter().position(|&b| b) {
            Some(c) => c,
            None => return false,
        };
        for r in 0..self.rows.len() {
            if self.rows[r].0[lead] {
                let payload_clone = payload.clone();
                let row_clone = row.clone();
                let (erow, epay) = &mut self.rows[r];
                xor_into(epay, &payload_clone);
                for (b, nb) in erow.iter_mut().zip(row_clone.iter()) {
                    *b ^= nb;
                }
            }
        }
        self.pivot[lead] = Some(self.rows.len());
        self.rows.push((row, payload));
        true
    }

    /// Recover the chunk once complete.
    pub fn recover(&self) -> Option<Vec<u8>> {
        if !self.is_complete() {
            return None;
        }
        let len = self.chunk_len? as usize;
        let mut out = vec![0u8; self.k * self.block_size];
        for c in 0..self.k {
            let r = self.pivot[c]?;
            let (_, payload) = &self.rows[r];
            out[c * self.block_size..(c + 1) * self.block_size].copy_from_slice(payload);
        }
        out.truncate(len);
        Some(out)
    }
}

/// Pre-change outer-code decoder: per-push clones of every pivot row and
/// payload touched, scalar field ops.
pub struct OuterDecoderRef {
    k: usize,
    object_len: Option<u64>,
    block_size: usize,
    pivot: Vec<Option<usize>>,
    rows: Vec<(Vec<u8>, Vec<u8>)>,
}

impl OuterDecoderRef {
    pub fn new(k: usize) -> Self {
        OuterDecoderRef {
            k,
            object_len: None,
            block_size: 0,
            pivot: vec![None; k],
            rows: Vec::new(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rows.len()
    }

    pub fn is_complete(&self) -> bool {
        self.rows.len() == self.k
    }

    /// Feed one encoded-chunk blob. Returns true if rank increased.
    pub fn push(&mut self, chunk_bytes: &[u8]) -> bool {
        if self.is_complete() {
            return false;
        }
        let Ok((header, payload)) = outer::parse_chunk(chunk_bytes) else { return false };
        if header.k_outer as usize != self.k {
            return false;
        }
        match self.object_len {
            None => {
                self.object_len = Some(header.object_len);
                self.block_size = payload.len();
            }
            Some(len) => {
                if len != header.object_len || payload.len() != self.block_size {
                    return false;
                }
            }
        }
        let mut row = outer::outer_row(header.outer_index, self.k);
        let mut pay = payload.to_vec();
        for c in 0..self.k {
            if row[c] == 0 {
                continue;
            }
            if let Some(pr) = self.pivot[c] {
                let factor = row[c];
                let (prow, ppay) = &self.rows[pr];
                let prow = prow.clone();
                let ppay = ppay.clone();
                for (v, pv) in row.iter_mut().zip(&prow) {
                    *v ^= gf256::mul(factor, *pv);
                }
                addmul_slice_ref(&mut pay, &ppay, factor);
            }
        }
        let Some(lead) = row.iter().position(|&v| v != 0) else { return false };
        let ilead = gf256::inv(row[lead]);
        for v in row.iter_mut() {
            *v = gf256::mul(*v, ilead);
        }
        scale_slice_ref(&mut pay, ilead);
        for r in 0..self.rows.len() {
            let factor = self.rows[r].0[lead];
            if factor != 0 {
                let row_c = row.clone();
                let pay_c = pay.clone();
                let (erow, epay) = &mut self.rows[r];
                for (v, nv) in erow.iter_mut().zip(&row_c) {
                    *v ^= gf256::mul(factor, *nv);
                }
                addmul_slice_ref(epay, &pay_c, factor);
            }
        }
        self.pivot[lead] = Some(self.rows.len());
        self.rows.push((row, pay));
        true
    }

    /// Recover the original object once complete.
    pub fn recover(&self) -> Option<Vec<u8>> {
        if !self.is_complete() {
            return None;
        }
        let len = self.object_len? as usize;
        let mut out = vec![0u8; self.k * self.block_size];
        for c in 0..self.k {
            let r = self.pivot[c]?;
            out[c * self.block_size..(c + 1) * self.block_size].copy_from_slice(&self.rows[r].1);
        }
        out.truncate(len);
        Some(out)
    }
}
