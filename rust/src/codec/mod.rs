//! Erasure-coding substrate: VAULT's dual-layer rateless codes.
//!
//! * [`outer`] — object → opaque encoded chunks (GF(256) random linear
//!   fountain, private index selection).
//! * [`rateless`] — chunk → infinite fragment stream (GF(2) XOR fountain;
//!   the hot path, mirrored by the L1 Pallas kernel).
//! * [`gf2`], [`gf256`], [`xor`] — the underlying linear algebra.
//!
//! End-to-end: `object --outer--> 10 chunks --inner--> 80 fragments each`,
//! redundancy (10/8)·(80/32) = 3.125× with the paper's defaults.

pub mod gf2;
pub mod gf256;
pub mod outer;
pub mod rateless;
pub mod reference;
pub mod xor;

pub use outer::{encode_object, EncodedChunk, ObjectId, OuterDecoder};
pub use rateless::{Fragment, InnerDecoder, InnerEncoder};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Hash256;
    use crate::params;
    use crate::util::rng::Rng;

    /// Full dual-layer pipeline: object → chunks → fragments → object.
    #[test]
    fn dual_layer_end_to_end() {
        let mut rng = Rng::new(77);
        let mut obj = vec![0u8; 200_000];
        rng.fill_bytes(&mut obj);

        let (id, chunks) = encode_object(&obj, b"owner-secret", params::K_OUTER, params::N_OUTER);

        // Inner-encode every chunk into fragments, as STORE would.
        let mut all_fragments: Vec<(Hash256, Vec<Fragment>)> = Vec::new();
        for c in &chunks {
            let enc = InnerEncoder::new(c.chash, &c.bytes, params::K_INNER);
            let frags = enc.fragments(&(0..params::R_INNER as u64).collect::<Vec<_>>());
            all_fragments.push((c.chash, frags));
        }

        // QUERY path: decode chunks from random fragment subsets, then
        // the object from K_outer chunks.
        let mut outer_dec = OuterDecoder::new(params::K_OUTER);
        for (chash, frags) in all_fragments.iter().take(params::K_OUTER + 1) {
            let mut dec = InnerDecoder::new(*chash, params::K_INNER);
            let mut order: Vec<usize> = (0..frags.len()).collect();
            rng.shuffle(&mut order);
            for &i in &order {
                dec.push(&frags[i]);
                if dec.is_complete() {
                    break;
                }
            }
            assert!(dec.is_complete());
            let chunk_bytes = dec.recover().unwrap();
            assert_eq!(Hash256::of(&chunk_bytes), *chash, "content addressing");
            outer_dec.push(&chunk_bytes);
            if outer_dec.is_complete() {
                break;
            }
        }
        assert!(outer_dec.is_complete());
        assert_eq!(outer_dec.recover().unwrap(), obj);
        assert_eq!(id.chunks.len(), params::N_OUTER);
    }

    /// Losing any (N-K) chunks and (R-K-ε) fragments per chunk still decodes.
    #[test]
    fn survives_maximum_design_loss() {
        let mut rng = Rng::new(78);
        let mut obj = vec![0u8; 50_000];
        rng.fill_bytes(&mut obj);
        let (_, chunks) = encode_object(&obj, b"s", params::K_OUTER, params::N_OUTER);

        // Keep only K_outer random chunks; from each keep only k+4 random fragments.
        let keep = rng.sample_indices(chunks.len(), params::K_OUTER);
        let mut outer_dec = OuterDecoder::new(params::K_OUTER);
        for &ci in &keep {
            let c = &chunks[ci];
            let enc = InnerEncoder::new(c.chash, &c.bytes, params::K_INNER);
            let surviving = rng.sample_indices(params::R_INNER, params::K_INNER + 4);
            let mut dec = InnerDecoder::new(c.chash, params::K_INNER);
            for &fi in &surviving {
                dec.push(&enc.fragment(fi as u64));
            }
            assert!(dec.is_complete(), "inner decode from k+4 of R fragments");
            outer_dec.push(&dec.recover().unwrap());
        }
        assert!(outer_dec.is_complete());
        assert_eq!(outer_dec.recover().unwrap(), obj);
    }

    /// Repair path: a new fragment generated from a decoded chunk equals
    /// the fragment the original encoder would produce (determinism).
    #[test]
    fn repair_regenerates_identical_fragments() {
        let mut rng = Rng::new(79);
        let mut obj = vec![0u8; 10_000];
        rng.fill_bytes(&mut obj);
        let (_, chunks) = encode_object(&obj, b"s", params::K_OUTER, params::N_OUTER);
        let c = &chunks[0];
        let enc = InnerEncoder::new(c.chash, &c.bytes, params::K_INNER);

        // New node receives k+3 fragments, decodes, re-encodes index 999.
        let mut dec = InnerDecoder::new(c.chash, params::K_INNER);
        for i in 0..(params::K_INNER as u64 + 3) {
            dec.push(&enc.fragment(i));
        }
        let recovered = dec.recover().unwrap();
        let enc2 = InnerEncoder::new(c.chash, &recovered, params::K_INNER);
        assert_eq!(enc2.fragment(999), enc.fragment(999));
    }
}
