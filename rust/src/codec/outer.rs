//! The outer rateless code: object → opaque encoded chunks (§4.2).
//!
//! The client applies a random linear fountain over GF(256) to the
//! object's `K_outer` source blocks, then uses *private information*
//! (its secret key + the object hash) to pick `N_outer` indices from the
//! infinite encoding stream. The index is embedded in the chunk payload
//! (it reveals nothing about which object the chunk belongs to), so the
//! chunk-to-object mapping stays opaque to everyone but the owner: a
//! targeted adversary "can do no better than compromising randomly
//! selected chunks".

use crate::crypto::sha2::{Digest, Sha256};
use crate::crypto::Hash256;
use crate::util::rng::HashDrbg;
use crate::wire::{Decode, Encode, Reader, WireResult, Writer};

use super::gf256;

/// Header prepended to every encoded chunk (serialized with [`crate::wire`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkHeader {
    /// Position of this chunk in the outer encoding stream.
    pub outer_index: u64,
    /// Outer-code dimension used at encode time.
    pub k_outer: u16,
    /// Original object length in bytes.
    pub object_len: u64,
}

crate::wire_struct!(ChunkHeader { outer_index, k_outer, object_len });

/// Opaque object handle: the chunk hashes returned by STORE (paper
/// Algorithm 1: "return chashes"). Only the owner holds it; IDs are
/// private to protect against targeted attacks (§4.1).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ObjectId {
    pub chunks: Vec<Hash256>,
}

crate::wire_struct!(ObjectId { chunks });

impl ObjectId {
    /// Content-addressed digest over all chunk hashes, streamed through
    /// one incremental SHA-256 (no per-call parts Vec).
    pub fn digest(&self) -> Hash256 {
        let mut h = Sha256::new();
        for c in &self.chunks {
            h.update(&c.0);
        }
        Hash256(h.finalize().into())
    }
}

/// Fixed-layout DRBG seed for outer-row derivation:
/// `"vault-outer-row-v1" ‖ index ‖ attempt` (18+8+4 bytes). Built once;
/// the retry loop patches only the attempt-counter bytes in place.
const OUTER_SEED_LEN: usize = 18 + 8 + 4;

/// Derive the GF(256) coefficient row for outer-stream index `index`
/// into `out` (resized to `k` bytes; no allocation once `out` has
/// capacity). Never all-zero.
pub fn outer_row_into(index: u64, k: usize, out: &mut Vec<u8>) {
    out.clear();
    out.resize(k, 0);
    let mut seed = [0u8; OUTER_SEED_LEN];
    seed[..18].copy_from_slice(b"vault-outer-row-v1");
    seed[18..26].copy_from_slice(&index.to_le_bytes());
    for attempt in 0u32.. {
        seed[26..30].copy_from_slice(&attempt.to_le_bytes());
        let mut drbg = HashDrbg::new(&seed);
        drbg.fill(out);
        if out.iter().any(|&c| c != 0) {
            return;
        }
    }
    unreachable!()
}

/// GF(256) coefficient row for outer-stream index `i`: `k` bytes, never
/// all-zero, derived from public information only (anyone holding a
/// chunk can derive its row from the embedded index).
pub fn outer_row(index: u64, k: usize) -> Vec<u8> {
    let mut row = Vec::with_capacity(k);
    outer_row_into(index, k, &mut row);
    row
}

/// Private index selection: `n` distinct indices drawn from the client's
/// secret and the object hash (§4.2 "uses its private key and the object
/// hash to deterministically select ... irreversible").
pub fn select_indices(secret: &[u8], object_hash: &Hash256, n: usize) -> Vec<u64> {
    let mut seed = Vec::with_capacity(21 + secret.len() + 32);
    seed.extend_from_slice(b"vault-outer-select-v1");
    seed.extend_from_slice(secret);
    seed.extend_from_slice(&object_hash.0);
    let mut drbg = HashDrbg::new(&seed);
    let mut out = Vec::with_capacity(n);
    let mut seen = std::collections::HashSet::with_capacity(n * 2);
    while out.len() < n {
        let idx = drbg.next_u64();
        if seen.insert(idx) {
            out.push(idx);
        }
    }
    out
}

/// One materialized encoded chunk: bytes = header ‖ payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EncodedChunk {
    pub chash: Hash256,
    pub bytes: Vec<u8>,
}

/// Outer-encode `object` into `n` opaque chunks selected by `secret`.
pub fn encode_object(object: &[u8], secret: &[u8], k: usize, n: usize) -> (ObjectId, Vec<EncodedChunk>) {
    assert!(k >= 1 && n >= k);
    let bs = object.len().div_ceil(k).max(1);
    let mut blocks = vec![0u8; k * bs];
    blocks[..object.len()].copy_from_slice(object);
    let ohash = Hash256::of(object);
    let indices = select_indices(secret, &ohash, n);

    let mut chunks = Vec::with_capacity(n);
    let mut hashes = Vec::with_capacity(n);
    let mut row = Vec::with_capacity(k);
    for &idx in &indices {
        outer_row_into(idx, k, &mut row);
        let header = ChunkHeader { outer_index: idx, k_outer: k as u16, object_len: object.len() as u64 };
        // Combine the blocks directly inside the wire buffer — no
        // staging payload Vec, no copy.
        let mut w = Writer::with_capacity(bs + 24);
        header.encode(&mut w);
        let payload = w.zeros(bs);
        for (j, &c) in row.iter().enumerate() {
            gf256::addmul_slice(payload, &blocks[j * bs..(j + 1) * bs], c);
        }
        let bytes = w.into_bytes();
        let chash = Hash256::of(&bytes);
        hashes.push(chash);
        chunks.push(EncodedChunk { chash, bytes });
    }
    (ObjectId { chunks: hashes }, chunks)
}

/// Parse a chunk blob into its header and payload.
pub fn parse_chunk(bytes: &[u8]) -> WireResult<(ChunkHeader, &[u8])> {
    let mut r = Reader::new(bytes);
    let header = ChunkHeader::decode(&mut r)?;
    let payload_len = r.remaining();
    let payload = r.take(payload_len)?;
    Ok((header, payload))
}

/// Incremental outer-code decoder over GF(256).
///
/// Same zero-alloc steady-state design as the inner
/// [`InnerDecoder`](super::rateless::InnerDecoder): flat coefficient and
/// payload arenas plus persistent scratch buffers, eliminated in place
/// with [`gf256::addmul_slice`] — no per-push row/payload clones. Only
/// the first accepted chunk (which fixes the block size) allocates.
pub struct OuterDecoder {
    k: usize,
    object_len: Option<u64>,
    block_size: usize,
    /// Accepted (pivot) rows so far.
    nrows: usize,
    /// pivot[c] = arena row with unit leading coefficient at column c.
    pivot: Vec<Option<usize>>,
    /// Reduced coefficient rows, row-major `k × k`.
    coeff: Vec<u8>,
    /// Reduced payload rows, row-major `k × block_size` (sized on first push).
    payloads: Vec<u8>,
    /// Scratch for the incoming row / payload being eliminated.
    scratch_row: Vec<u8>,
    scratch_pay: Vec<u8>,
}

impl OuterDecoder {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        OuterDecoder {
            k,
            object_len: None,
            block_size: 0,
            nrows: 0,
            pivot: vec![None; k],
            coeff: vec![0u8; k * k],
            payloads: Vec::new(),
            scratch_row: vec![0u8; k],
            scratch_pay: Vec::new(),
        }
    }

    pub fn rank(&self) -> usize {
        self.nrows
    }
    pub fn is_complete(&self) -> bool {
        self.nrows == self.k
    }

    /// Feed one encoded-chunk blob. Returns true if rank increased.
    pub fn push(&mut self, chunk_bytes: &[u8]) -> bool {
        if self.is_complete() {
            return false;
        }
        let Ok((header, payload)) = parse_chunk(chunk_bytes) else { return false };
        if header.k_outer as usize != self.k {
            return false;
        }
        match self.object_len {
            None => {
                self.object_len = Some(header.object_len);
                self.block_size = payload.len();
                self.payloads.resize(self.k * self.block_size, 0);
                self.scratch_pay.resize(self.block_size, 0);
            }
            Some(len) => {
                if len != header.object_len || payload.len() != self.block_size {
                    return false;
                }
            }
        }
        let k = self.k;
        let bs = self.block_size;
        // Move the scratch buffers out so elimination can borrow the
        // arenas immutably alongside them (`take` swaps in empty Vecs —
        // no allocation).
        let mut row = std::mem::take(&mut self.scratch_row);
        let mut pay = std::mem::take(&mut self.scratch_pay);
        outer_row_into(header.outer_index, k, &mut row);
        pay.copy_from_slice(payload);

        // Eliminate against existing pivots. Pivot rows are reduced
        // (unit leading coefficient at their column, zeros before it),
        // so an ascending column scan only touches coefficients ≥ c.
        for c in 0..k {
            if row[c] == 0 {
                continue;
            }
            if let Some(pr) = self.pivot[c] {
                let factor = row[c];
                gf256::addmul_slice(&mut row, &self.coeff[pr * k..(pr + 1) * k], factor);
                gf256::addmul_slice(&mut pay, &self.payloads[pr * bs..(pr + 1) * bs], factor);
            }
        }
        let accepted = match row.iter().position(|&v| v != 0) {
            None => false, // linearly dependent
            Some(lead) => {
                // Normalize to unit pivot.
                let ilead = gf256::inv(row[lead]);
                gf256::scale_slice(&mut row, ilead);
                gf256::scale_slice(&mut pay, ilead);
                // Back-substitute into existing rows.
                for r in 0..self.nrows {
                    let factor = self.coeff[r * k + lead];
                    if factor != 0 {
                        gf256::addmul_slice(&mut self.coeff[r * k..(r + 1) * k], &row, factor);
                        gf256::addmul_slice(
                            &mut self.payloads[r * bs..(r + 1) * bs],
                            &pay,
                            factor,
                        );
                    }
                }
                // Install the new pivot row into the arenas.
                let n = self.nrows;
                self.coeff[n * k..(n + 1) * k].copy_from_slice(&row);
                self.payloads[n * bs..(n + 1) * bs].copy_from_slice(&pay);
                self.pivot[lead] = Some(n);
                self.nrows += 1;
                true
            }
        };
        self.scratch_row = row;
        self.scratch_pay = pay;
        accepted
    }

    /// Recover the original object once complete.
    pub fn recover(&self) -> Option<Vec<u8>> {
        if !self.is_complete() {
            return None;
        }
        let len = self.object_len? as usize;
        let mut out = vec![0u8; self.k * self.block_size];
        for c in 0..self.k {
            let r = self.pivot[c]?;
            out[c * self.block_size..(c + 1) * self.block_size]
                .copy_from_slice(&self.payloads[r * self.block_size..(r + 1) * self.block_size]);
        }
        out.truncate(len);
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_obj(seed: u64, len: usize) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0u8; len];
        rng.fill_bytes(&mut v);
        v
    }

    #[test]
    fn encode_decode_all_chunks() {
        for (seed, len) in [(1u64, 100_000usize), (2, 1), (3, 7), (4, 8), (5, 65536)] {
            let obj = rand_obj(seed, len);
            let (id, chunks) = encode_object(&obj, b"secret", 8, 10);
            assert_eq!(id.chunks.len(), 10);
            let mut dec = OuterDecoder::new(8);
            for c in &chunks {
                dec.push(&c.bytes);
                if dec.is_complete() {
                    break;
                }
            }
            assert!(dec.is_complete());
            assert_eq!(dec.recover().unwrap(), obj);
        }
    }

    #[test]
    fn any_k_of_n_subset_decodes() {
        // GF(256) rows: essentially every k-subset is full rank.
        let obj = rand_obj(10, 10_000);
        let (_, chunks) = encode_object(&obj, b"s", 8, 10);
        let mut rng = Rng::new(11);
        let mut failures = 0;
        for _ in 0..20 {
            let pick = rng.sample_indices(10, 8);
            let mut dec = OuterDecoder::new(8);
            for &i in &pick {
                dec.push(&chunks[i].bytes);
            }
            if dec.is_complete() {
                assert_eq!(dec.recover().unwrap(), obj);
            } else {
                failures += 1;
            }
        }
        // P(singular 8x8 over GF(256)) ≈ 0.4%; 20 trials should all pass.
        assert_eq!(failures, 0);
    }

    #[test]
    fn chunks_are_opaque_and_content_addressed() {
        let obj = rand_obj(20, 4096);
        let (id_a, chunks_a) = encode_object(&obj, b"alice", 8, 10);
        let (id_b, chunks_b) = encode_object(&obj, b"bob", 8, 10);
        // Different secrets pick different stream indices ⇒ different
        // chunks & IDs for the same object (owner privacy).
        assert_ne!(id_a, id_b);
        for c in &chunks_a {
            assert_eq!(c.chash, Hash256::of(&c.bytes));
        }
        // Same secret is deterministic.
        let (id_a2, chunks_a2) = encode_object(&obj, b"alice", 8, 10);
        assert_eq!(id_a, id_a2);
        assert_eq!(chunks_a, chunks_a2);
        drop(chunks_b);
    }

    #[test]
    fn select_indices_distinct_and_private() {
        let h = Hash256::of(b"obj");
        let a = select_indices(b"k1", &h, 10);
        let b = select_indices(b"k2", &h, 10);
        assert_ne!(a, b);
        let set: std::collections::HashSet<_> = a.iter().collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn wrong_k_chunks_rejected() {
        let obj = rand_obj(30, 1000);
        let (_, chunks) = encode_object(&obj, b"s", 4, 6);
        let mut dec = OuterDecoder::new(8);
        assert!(!dec.push(&chunks[0].bytes));
        assert_eq!(dec.rank(), 0);
    }

    #[test]
    fn dependent_chunk_does_not_advance() {
        let obj = rand_obj(31, 1000);
        let (_, chunks) = encode_object(&obj, b"s", 8, 10);
        let mut dec = OuterDecoder::new(8);
        assert!(dec.push(&chunks[0].bytes));
        assert!(!dec.push(&chunks[0].bytes));
        assert_eq!(dec.rank(), 1);
    }

    #[test]
    fn object_id_wire_roundtrip() {
        use crate::wire::{Decode, Encode};
        let obj = rand_obj(32, 100);
        let (id, _) = encode_object(&obj, b"s", 8, 10);
        let got = ObjectId::from_bytes(&id.to_bytes()).unwrap();
        assert_eq!(got, id);
    }
}

impl OuterDecoder {
    /// Test/debug introspection.
    pub fn debug_pivots(&self) -> Vec<Option<usize>> {
        self.pivot.clone()
    }
}
