//! GF(2^8) arithmetic for the *outer* fountain code.
//!
//! The outer code works over k_outer = 8 source blocks; random GF(2)
//! rows at that size would fail to reach full rank too often (a random
//! 8×8 GF(2) matrix is singular with probability ≈ 0.71), so the outer
//! layer uses random linear combinations over GF(256) instead, where an
//! 8×8 random matrix is full rank with probability ≈ 0.9961 and any 8
//! of the 10 stored chunks decode essentially always. The inner code
//! (the hot path) stays GF(2)/XOR — see DESIGN.md §Substitutions.
//!
//! Standard AES-polynomial field (0x11B) with log/exp tables.

use std::sync::OnceLock;

struct Tables {
    exp: [u8; 512],
    log: [u8; 256],
}

fn tables() -> &'static Tables {
    static CELL: OnceLock<Tables> = OnceLock::new();
    CELL.get_or_init(|| {
        let mut exp = [0u8; 512];
        let mut log = [0u8; 256];
        let mut x: u16 = 1;
        for i in 0..255 {
            exp[i] = x as u8;
            log[x as usize] = i as u8;
            // Multiply by the generator 0x03 (note: 0x02 is NOT a
            // generator of GF(256)/0x11B — its order is only 51).
            let mut x2 = x << 1;
            if x2 & 0x100 != 0 {
                x2 ^= 0x11B;
            }
            x = x2 ^ x;
        }
        for i in 255..512 {
            exp[i] = exp[i - 255];
        }
        Tables { exp, log }
    })
}

/// Multiply in GF(256).
pub fn mul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        return 0;
    }
    let t = tables();
    t.exp[t.log[a as usize] as usize + t.log[b as usize] as usize]
}

/// Multiplicative inverse; panics on 0.
pub fn inv(a: u8) -> u8 {
    assert_ne!(a, 0, "gf256 inverse of zero");
    let t = tables();
    t.exp[255 - t.log[a as usize] as usize]
}

pub fn div(a: u8, b: u8) -> u8 {
    mul(a, inv(b))
}

/// 256-entry product table for a fixed coefficient `c`: `tbl[s] = c*s`.
/// Building it costs 255 log/exp lookups, amortized over the slice; the
/// main loops below then run branch-free (`tbl[0] == 0`, so zero bytes
/// need no special case).
#[inline]
pub fn mul_table(c: u8) -> [u8; 256] {
    let mut tbl = [0u8; 256];
    if c == 0 {
        return tbl;
    }
    let t = tables();
    let lc = t.log[c as usize] as usize;
    for (s, e) in tbl.iter_mut().enumerate().skip(1) {
        *e = t.exp[lc + t.log[s] as usize];
    }
    tbl
}

/// Below this length the per-call table build is not amortized and the
/// log/exp loop wins (coefficient-row updates are k ≤ 16 bytes).
const TABLE_CUTOVER: usize = 64;

/// dst += c * src (GF(256) — addition is XOR). The outer-code hot loop:
/// per-call product table + 8-byte unrolled branch-free main loop.
pub fn addmul_slice(dst: &mut [u8], src: &[u8], c: u8) {
    assert_eq!(dst.len(), src.len());
    if c == 0 {
        return;
    }
    if c == 1 {
        super::xor::xor_into(dst, src);
        return;
    }
    if dst.len() < TABLE_CUTOVER {
        let t = tables();
        let lc = t.log[c as usize] as usize;
        for (d, &s) in dst.iter_mut().zip(src) {
            if s != 0 {
                *d ^= t.exp[lc + t.log[s as usize] as usize];
            }
        }
        return;
    }
    let tbl = mul_table(c);
    let head = dst.len() & !7;
    for (d8, s8) in dst[..head].chunks_exact_mut(8).zip(src[..head].chunks_exact(8)) {
        d8[0] ^= tbl[s8[0] as usize];
        d8[1] ^= tbl[s8[1] as usize];
        d8[2] ^= tbl[s8[2] as usize];
        d8[3] ^= tbl[s8[3] as usize];
        d8[4] ^= tbl[s8[4] as usize];
        d8[5] ^= tbl[s8[5] as usize];
        d8[6] ^= tbl[s8[6] as usize];
        d8[7] ^= tbl[s8[7] as usize];
    }
    for (d, &s) in dst[head..].iter_mut().zip(&src[head..]) {
        *d ^= tbl[s as usize];
    }
}

/// Disjoint (`pivot`, `other`) row pair from one backing slice — the
/// split_at_mut dance that lets elimination read the pivot row while
/// mutating another without cloning either.
#[inline]
fn pivot_pair_mut<T>(rows: &mut [T], p: usize, r: usize) -> (&T, &mut T) {
    debug_assert_ne!(p, r);
    if p < r {
        let (lo, hi) = rows.split_at_mut(r);
        (&lo[p], &mut hi[0])
    } else {
        let (lo, hi) = rows.split_at_mut(p);
        (&hi[0], &mut lo[r])
    }
}

/// Solve the dense GF(256) system `C x = F` in place, returning the
/// recovered blocks in source order. `coeff` is row-major k×k, `payload`
/// rows are the combined blocks. Returns `None` if singular. Both inputs
/// are consumed (left in reduced/emptied form).
pub fn solve(coeff: &mut [Vec<u8>], payload: &mut [Vec<u8>]) -> Option<Vec<Vec<u8>>> {
    let k = coeff.len();
    assert_eq!(payload.len(), k);
    let mut perm = vec![0usize; k];
    let mut used = vec![false; k];
    for col in 0..k {
        // Pivot: first unused row with nonzero coefficient.
        let p = (0..k).find(|&r| !used[r] && coeff[r][col] != 0)?;
        used[p] = true;
        perm[col] = p;
        // Normalize pivot row.
        let pc = coeff[p][col];
        if pc != 1 {
            let ipc = inv(pc);
            scale_slice(&mut coeff[p], ipc);
            scale_slice(&mut payload[p], ipc);
        }
        // Eliminate from all other rows, borrowing the pivot row in
        // place rather than cloning it per elimination.
        for r in 0..k {
            if r == p || coeff[r][col] == 0 {
                continue;
            }
            let factor = coeff[r][col];
            let (pc_row, rc_row) = pivot_pair_mut(coeff, p, r);
            addmul_slice(rc_row, pc_row, factor);
            let (pp_row, rp_row) = pivot_pair_mut(payload, p, r);
            addmul_slice(rp_row, pp_row, factor);
        }
    }
    Some(perm.iter().map(|&p| std::mem::take(&mut payload[p])).collect())
}

/// In-place slice scaling by `c` (same table strategy as
/// [`addmul_slice`]).
pub fn scale_slice(data: &mut [u8], c: u8) {
    if c == 1 {
        return;
    }
    if c == 0 {
        data.fill(0);
        return;
    }
    if data.len() < TABLE_CUTOVER {
        let t = tables();
        let lc = t.log[c as usize] as usize;
        for d in data.iter_mut() {
            if *d != 0 {
                *d = t.exp[lc + t.log[*d as usize] as usize];
            }
        }
        return;
    }
    let tbl = mul_table(c);
    let head = data.len() & !7;
    for d8 in data[..head].chunks_exact_mut(8) {
        d8[0] = tbl[d8[0] as usize];
        d8[1] = tbl[d8[1] as usize];
        d8[2] = tbl[d8[2] as usize];
        d8[3] = tbl[d8[3] as usize];
        d8[4] = tbl[d8[4] as usize];
        d8[5] = tbl[d8[5] as usize];
        d8[6] = tbl[d8[6] as usize];
        d8[7] = tbl[d8[7] as usize];
    }
    for d in data[head..].iter_mut() {
        *d = tbl[*d as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn field_axioms() {
        let mut rng = Rng::new(70);
        for _ in 0..500 {
            let a = rng.next_u32() as u8;
            let b = rng.next_u32() as u8;
            let c = rng.next_u32() as u8;
            assert_eq!(mul(a, b), mul(b, a));
            assert_eq!(mul(a, mul(b, c)), mul(mul(a, b), c));
            assert_eq!(mul(a, b ^ c), mul(a, b) ^ mul(a, c)); // distributive
            assert_eq!(mul(a, 1), a);
            assert_eq!(mul(a, 0), 0);
        }
    }

    #[test]
    fn inverse_works() {
        for a in 1..=255u8 {
            assert_eq!(mul(a, inv(a)), 1, "a={a}");
            assert_eq!(div(mul(7, a), a), 7);
        }
    }

    #[test]
    fn known_products() {
        // AES field: 0x53 * 0xCA = 0x01 (classic inverse pair)
        assert_eq!(mul(0x53, 0xCA), 0x01);
        assert_eq!(mul(2, 0x80), 0x1B); // x * x^7 = x^8 = 0x1B
    }

    #[test]
    fn addmul_matches_scalar() {
        let mut rng = Rng::new(71);
        // Lengths straddle the table cutover and the 8-byte unroll tail.
        for len in [0usize, 1, 7, 8, 63, 64, 65, 71, 256, 257, 1000] {
            let mut dst = vec![0u8; len];
            let mut src = vec![0u8; len];
            rng.fill_bytes(&mut dst);
            rng.fill_bytes(&mut src);
            for c in [0u8, 1, 2, 0xA7, 0xFF] {
                let want: Vec<u8> =
                    dst.iter().zip(&src).map(|(&d, &s)| d ^ mul(c, s)).collect();
                addmul_slice(&mut dst, &src, c);
                assert_eq!(dst, want, "len={len} c={c}");
            }
        }
    }

    #[test]
    fn scale_matches_scalar() {
        let mut rng = Rng::new(73);
        for len in [0usize, 1, 7, 8, 63, 64, 65, 71, 257] {
            for c in [0u8, 1, 3, 0x53, 0xFE] {
                let mut data = vec![0u8; len];
                rng.fill_bytes(&mut data);
                let want: Vec<u8> = data.iter().map(|&d| mul(c, d)).collect();
                scale_slice(&mut data, c);
                assert_eq!(data, want, "len={len} c={c}");
            }
        }
    }

    #[test]
    fn mul_table_matches_mul() {
        for c in [0u8, 1, 2, 0x80, 0xA7, 0xFF] {
            let tbl = mul_table(c);
            for s in 0..=255u8 {
                assert_eq!(tbl[s as usize], mul(c, s), "c={c} s={s}");
            }
        }
    }

    #[test]
    fn solve_recovers_random_system() {
        let mut rng = Rng::new(72);
        let k = 8;
        let blk = 64;
        let blocks: Vec<Vec<u8>> = (0..k)
            .map(|_| {
                let mut b = vec![0u8; blk];
                rng.fill_bytes(&mut b);
                b
            })
            .collect();
        // Build k random combinations.
        let mut coeff: Vec<Vec<u8>> = Vec::new();
        let mut payload: Vec<Vec<u8>> = Vec::new();
        loop {
            coeff.clear();
            payload.clear();
            for _ in 0..k {
                let row: Vec<u8> = (0..k).map(|_| rng.next_u32() as u8).collect();
                let mut p = vec![0u8; blk];
                for (c, b) in row.iter().zip(&blocks) {
                    addmul_slice(&mut p, b, *c);
                }
                coeff.push(row);
                payload.push(p);
            }
            if let Some(got) = solve(&mut coeff.clone(), &mut payload.clone()) {
                assert_eq!(got, blocks);
                break;
            }
            // singular draw (prob ~0.4%) — retry
        }
    }

    #[test]
    fn solve_singular_returns_none() {
        let k = 4;
        let mut coeff: Vec<Vec<u8>> = vec![vec![1, 2, 3, 4]; k]; // rank 1
        let mut payload: Vec<Vec<u8>> = vec![vec![0u8; 8]; k];
        assert!(solve(&mut coeff, &mut payload).is_none());
    }
}
