//! Long-running node support: persistent fragment storage.
//!
//! The protocol state machine ([`crate::proto::peer`]) keeps fragments
//! in memory; a real deployment must survive process restarts without
//! losing its chunk-group memberships. [`storage::DiskStore`] provides
//! the crash-safe on-disk fragment store the `vault node` daemon
//! snapshots into and recovers from.

pub mod storage;
