//! Long-running node support: persistent fragment storage.
//!
//! The protocol state machine ([`crate::proto::peer`]) keeps fragments
//! in memory; a real deployment must survive process restarts without
//! losing its chunk-group memberships. [`storage::DiskStore`] provides
//! the crash-safe on-disk fragment store, and [`wal`] the event-sourced
//! write-ahead log the peer appends every durable mutation to — the
//! restart/recovery path (ISSUE 6) replays the WAL and re-joins the
//! node's groups.

pub mod health;
pub mod ranking;
pub mod storage;
pub mod wal;
