//! Crash-safe on-disk fragment store.
//!
//! Layout: `<root>/<chash-hex>.frag`, one file per stored fragment,
//! containing the wire-encoded [`StoredFragment`] (fragment + own
//! selection proof + expiry) followed by an 8-byte FNV-64 checksum
//! trailer. Writes go through a temp file + fsync + rename + directory
//! fsync so a crash never leaves a torn record *and* never silently
//! drops a completed one (rename alone is not durable until the parent
//! directory's metadata hits the platter). Stale `.tmp-*` files from a
//! crash between create and rename are swept at `open`. Damaged records
//! are reported as [`LoadOutcome::Corrupt`] — distinguishable from
//! absence — and counted, so the recovery path can assert on exactly
//! how much was lost.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::codec::rateless::Fragment;
use crate::crypto::vrf::VrfProof;
use crate::crypto::Hash256;
use crate::wire::{Decode, Encode};

use super::wal::{fnv64, fsync_dir};

/// Everything a node must persist per fragment to resume group duty.
#[derive(Clone, Debug, PartialEq)]
pub struct StoredFragment {
    pub chash: Hash256,
    pub frag: Fragment,
    pub proof: VrfProof,
    pub expires_ms: u64,
}

crate::wire_struct!(StoredFragment { chash, frag, proof, expires_ms });

/// The tri-state a read can land in. `Corrupt` is NOT `Absent`: a
/// corrupt record means this node *did* accept custody and lost the
/// bytes — the caller must count it against durability and let the
/// group repair it, not pretend it never held the fragment.
#[derive(Clone, Debug, PartialEq)]
pub enum LoadOutcome {
    Loaded(StoredFragment),
    Absent,
    Corrupt,
}

/// What `load_all` recovered, plus the damage tally the restart
/// scenarios assert on.
#[derive(Debug, Default)]
pub struct Recovered {
    pub fragments: Vec<StoredFragment>,
    /// `.frag` files that failed checksum or decode (skipped).
    pub corrupt_records: u64,
    /// Stale `.tmp-*` files swept by `open` since construction.
    pub tmp_swept: u64,
}

pub struct DiskStore {
    root: PathBuf,
    /// Disambiguates concurrent temp files (a wall-clock name collides
    /// for two writes in the same millisecond).
    tmp_seq: AtomicU64,
    /// Stale temp files removed during `open` — recovery metric.
    tmp_swept: AtomicU64,
    /// Parent-directory fsyncs issued (after rename and after remove) —
    /// lets tests assert the durability path is actually exercised.
    dir_syncs: AtomicU64,
}

impl DiskStore {
    /// Open the store, creating the root if needed and sweeping any
    /// `.tmp-*` leftovers from a crash between temp-create and rename.
    /// Valid `.frag` records are never touched by the sweep.
    pub fn open(root: impl Into<PathBuf>) -> std::io::Result<DiskStore> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        let mut swept = 0u64;
        for entry in std::fs::read_dir(&root)? {
            let entry = entry?;
            if entry.file_name().to_string_lossy().starts_with(".tmp-") {
                std::fs::remove_file(entry.path())?;
                swept += 1;
            }
        }
        let store = DiskStore {
            root,
            tmp_seq: AtomicU64::new(0),
            tmp_swept: AtomicU64::new(swept),
            dir_syncs: AtomicU64::new(0),
        };
        if swept > 0 {
            store.sync_root()?;
        }
        Ok(store)
    }

    fn path_for(&self, chash: &Hash256) -> PathBuf {
        self.root.join(format!("{}.frag", chash.to_hex()))
    }

    fn sync_root(&self) -> std::io::Result<()> {
        fsync_dir(&self.root)?;
        self.dir_syncs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Record frame: wire bytes + FNV-64 trailer. The wire codec alone
    /// accepts a bit-flipped payload byte (lengths still parse); the
    /// checksum makes any single-byte damage detectable.
    fn frame(rec: &StoredFragment) -> Vec<u8> {
        let mut bytes = rec.to_bytes();
        let sum = fnv64(&bytes);
        bytes.extend_from_slice(&sum.to_le_bytes());
        bytes
    }

    fn unframe(bytes: &[u8]) -> Option<StoredFragment> {
        if bytes.len() < 8 {
            return None;
        }
        let (payload, trailer) = bytes.split_at(bytes.len() - 8);
        if fnv64(payload) != u64::from_le_bytes(trailer.try_into().unwrap()) {
            return None;
        }
        StoredFragment::from_bytes(payload).ok()
    }

    /// Atomic durable write: temp file in the same directory, fsync,
    /// rename, then fsync the directory so the rename itself survives
    /// power loss. The temp name is derived from the chunk hash plus a
    /// per-store counter, so concurrent `put`s never clobber each
    /// other's half-written files.
    pub fn put(&self, rec: &StoredFragment) -> std::io::Result<()> {
        let final_path = self.path_for(&rec.chash);
        let seq = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        let tmp_path = self.root.join(format!(".tmp-{}-{seq}", rec.chash.to_hex()));
        {
            let mut f = std::fs::File::create(&tmp_path)?;
            f.write_all(&Self::frame(rec))?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp_path, &final_path)?;
        self.sync_root()?;
        Ok(())
    }

    /// Tri-state read: corruption is not absence (see [`LoadOutcome`]).
    pub fn get(&self, chash: &Hash256) -> std::io::Result<LoadOutcome> {
        let bytes = match std::fs::read(self.path_for(chash)) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(LoadOutcome::Absent)
            }
            Err(e) => return Err(e),
        };
        Ok(match Self::unframe(&bytes) {
            Some(rec) => LoadOutcome::Loaded(rec),
            None => LoadOutcome::Corrupt,
        })
    }

    /// Remove a record and make the removal durable (directory fsync —
    /// without it a crash can resurrect the file and the node would
    /// claim custody of a fragment the protocol already released).
    pub fn remove(&self, chash: &Hash256) -> std::io::Result<bool> {
        match std::fs::remove_file(self.path_for(chash)) {
            Ok(()) => {
                self.sync_root()?;
                Ok(true)
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e),
        }
    }

    /// Recover every valid fragment (crash recovery path), counting —
    /// not hiding — the ones that failed checksum or decode.
    pub fn load_all(&self) -> std::io::Result<Recovered> {
        let mut out = Recovered {
            tmp_swept: self.tmp_swept.load(Ordering::Relaxed),
            ..Recovered::default()
        };
        for entry in std::fs::read_dir(&self.root)? {
            let entry = entry?;
            let path = entry.path();
            if path.extension().map(|e| e != "frag").unwrap_or(true) {
                continue;
            }
            match std::fs::read(&path).ok().as_deref().and_then(Self::unframe) {
                Some(rec) => out.fragments.push(rec),
                None => out.corrupt_records += 1,
            }
        }
        Ok(out)
    }

    /// Parent-directory fsyncs issued so far (test observability).
    pub fn dir_syncs(&self) -> u64 {
        self.dir_syncs.load(Ordering::Relaxed)
    }

    /// Stale `.tmp-*` files swept at `open`.
    pub fn tmp_swept(&self) -> u64 {
        self.tmp_swept.load(Ordering::Relaxed)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::ed25519::SigningKey;
    use crate::crypto::vrf;
    use crate::util;

    fn rec(tag: u8) -> StoredFragment {
        let sk = SigningKey::from_seed(&[tag; 32]);
        let (_, proof) = vrf::prove(&sk, &[tag]);
        StoredFragment {
            chash: Hash256::of(&[tag]),
            frag: Fragment { index: tag as u64, chunk_len: 100, payload: vec![tag; 64] },
            proof,
            expires_ms: 12345,
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("vault-store-test-{tag}-{}", util::now_ms()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn put_get_remove_roundtrip() {
        let store = DiskStore::open(tmpdir("rt")).unwrap();
        let r = rec(1);
        store.put(&r).unwrap();
        assert_eq!(store.get(&r.chash).unwrap(), LoadOutcome::Loaded(r.clone()));
        assert!(store.remove(&r.chash).unwrap());
        assert_eq!(store.get(&r.chash).unwrap(), LoadOutcome::Absent);
        assert!(!store.remove(&r.chash).unwrap());
    }

    #[test]
    fn put_and_remove_fsync_the_parent_directory() {
        // ISSUE 6 satellite 1: rename/unlink without a directory fsync
        // is not durable. Assert the fsync path actually runs — once
        // per put, once per effective remove, none for a no-op remove.
        let store = DiskStore::open(tmpdir("fsync")).unwrap();
        assert_eq!(store.dir_syncs(), 0);
        let r = rec(1);
        store.put(&r).unwrap();
        assert_eq!(store.dir_syncs(), 1, "put must fsync the directory after rename");
        store.put(&rec(2)).unwrap();
        assert_eq!(store.dir_syncs(), 2);
        assert!(store.remove(&r.chash).unwrap());
        assert_eq!(store.dir_syncs(), 3, "remove must fsync the directory after unlink");
        assert!(!store.remove(&r.chash).unwrap());
        assert_eq!(store.dir_syncs(), 3, "a no-op remove has nothing to make durable");
    }

    #[test]
    fn stale_tmp_files_are_swept_at_open() {
        // ISSUE 6 satellite 2: a crash between temp-create and rename
        // leaves `.tmp-*` behind; open must sweep it without touching
        // valid records.
        let dir = tmpdir("sweep");
        {
            let store = DiskStore::open(&dir).unwrap();
            store.put(&rec(1)).unwrap();
        }
        std::fs::write(dir.join(".tmp-deadbeef-0"), b"half-written").unwrap();
        let store = DiskStore::open(&dir).unwrap();
        assert_eq!(store.tmp_swept(), 1, "the planted temp file must be swept");
        assert!(!dir.join(".tmp-deadbeef-0").exists());
        let recovered = store.load_all().unwrap();
        assert_eq!(recovered.fragments, vec![rec(1)], "valid records must survive the sweep");
        assert_eq!(recovered.tmp_swept, 1);
    }

    #[test]
    fn load_all_recovers_everything() {
        let store = DiskStore::open(tmpdir("all")).unwrap();
        for t in 1..=5 {
            store.put(&rec(t)).unwrap();
        }
        let mut recovered = store.load_all().unwrap();
        recovered.fragments.sort_by_key(|r| r.frag.index);
        assert_eq!(recovered.fragments.len(), 5);
        assert_eq!(recovered.fragments[0], rec(1));
        assert_eq!(recovered.corrupt_records, 0);
    }

    #[test]
    fn corrupt_records_are_counted_not_hidden() {
        // ISSUE 6 satellite 3: corruption and absence are different
        // outcomes, and recovery counts what it skipped.
        let dir = tmpdir("corrupt");
        let store = DiskStore::open(&dir).unwrap();
        store.put(&rec(2)).unwrap();
        std::fs::write(dir.join("garbage.frag"), b"not a fragment").unwrap();
        let recovered = store.load_all().unwrap();
        assert_eq!(recovered.fragments.len(), 1);
        assert_eq!(recovered.corrupt_records, 1, "the garbage record must be counted");

        // A bit-flipped payload byte still wire-decodes; the checksum
        // trailer is what catches it.
        let r = rec(3);
        store.put(&r).unwrap();
        let path = dir.join(format!("{}.frag", r.chash.to_hex()));
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert_eq!(store.get(&r.chash).unwrap(), LoadOutcome::Corrupt);
        assert_eq!(store.get(&Hash256::of(b"never-stored")).unwrap(), LoadOutcome::Absent);
        assert_eq!(store.load_all().unwrap().corrupt_records, 2);
    }

    #[test]
    fn burst_of_puts_leaves_no_temp_files() {
        // Same-millisecond writes used to collide on a wall-clock temp
        // name; the hash+counter name must keep every record intact and
        // leave nothing behind.
        let dir = tmpdir("burst");
        let store = DiskStore::open(&dir).unwrap();
        for t in 1..=20 {
            store.put(&rec(t)).unwrap();
        }
        assert_eq!(store.load_all().unwrap().fragments.len(), 20);
        let leftovers = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with(".tmp-"))
            .count();
        assert_eq!(leftovers, 0, "temp files must all be renamed away");
    }

    #[test]
    fn overwrite_is_atomic_replacement() {
        let store = DiskStore::open(tmpdir("ow")).unwrap();
        let mut r = rec(3);
        store.put(&r).unwrap();
        r.expires_ms = 999;
        store.put(&r).unwrap();
        match store.get(&r.chash).unwrap() {
            LoadOutcome::Loaded(got) => assert_eq!(got.expires_ms, 999),
            other => panic!("expected the replacement record, got {other:?}"),
        }
        assert_eq!(store.load_all().unwrap().fragments.len(), 1);
    }
}
