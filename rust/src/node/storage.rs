//! Crash-safe on-disk fragment store.
//!
//! Layout: `<root>/<chash-hex>.frag`, one file per stored fragment,
//! containing the wire-encoded [`StoredFragment`] (fragment + own
//! selection proof + expiry). Writes go through a temp file + rename so
//! a crash never leaves a torn record; unparseable files are skipped at
//! recovery (treated as lost fragments — the group repairs them).

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::codec::rateless::Fragment;
use crate::crypto::vrf::VrfProof;
use crate::crypto::Hash256;
use crate::wire::{Decode, Encode};

/// Everything a node must persist per fragment to resume group duty.
#[derive(Clone, Debug, PartialEq)]
pub struct StoredFragment {
    pub chash: Hash256,
    pub frag: Fragment,
    pub proof: VrfProof,
    pub expires_ms: u64,
}

crate::wire_struct!(StoredFragment { chash, frag, proof, expires_ms });

pub struct DiskStore {
    root: PathBuf,
    /// Disambiguates concurrent temp files (a wall-clock name collides
    /// for two writes in the same millisecond).
    tmp_seq: AtomicU64,
}

impl DiskStore {
    pub fn open(root: impl Into<PathBuf>) -> std::io::Result<DiskStore> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        Ok(DiskStore { root, tmp_seq: AtomicU64::new(0) })
    }

    fn path_for(&self, chash: &Hash256) -> PathBuf {
        self.root.join(format!("{}.frag", chash.to_hex()))
    }

    /// Atomic write: temp file in the same directory, fsync, rename.
    /// The temp name is derived from the chunk hash plus a per-store
    /// counter, so concurrent `put`s never clobber each other's
    /// half-written files.
    pub fn put(&self, rec: &StoredFragment) -> std::io::Result<()> {
        let final_path = self.path_for(&rec.chash);
        let seq = self.tmp_seq.fetch_add(1, Ordering::Relaxed);
        let tmp_path = self.root.join(format!(".tmp-{}-{seq}", rec.chash.to_hex()));
        {
            let mut f = std::fs::File::create(&tmp_path)?;
            f.write_all(&rec.to_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp_path, &final_path)?;
        Ok(())
    }

    pub fn get(&self, chash: &Hash256) -> Option<StoredFragment> {
        let bytes = std::fs::read(self.path_for(chash)).ok()?;
        StoredFragment::from_bytes(&bytes).ok()
    }

    pub fn remove(&self, chash: &Hash256) -> std::io::Result<bool> {
        match std::fs::remove_file(self.path_for(chash)) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e),
        }
    }

    /// Recover every parseable fragment (crash recovery path).
    pub fn load_all(&self) -> std::io::Result<Vec<StoredFragment>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.root)? {
            let entry = entry?;
            let path = entry.path();
            if path.extension().map(|e| e != "frag").unwrap_or(true) {
                continue;
            }
            if let Ok(bytes) = std::fs::read(&path) {
                if let Ok(rec) = StoredFragment::from_bytes(&bytes) {
                    out.push(rec);
                }
            }
        }
        Ok(out)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::ed25519::SigningKey;
    use crate::crypto::vrf;
    use crate::util;

    fn rec(tag: u8) -> StoredFragment {
        let sk = SigningKey::from_seed(&[tag; 32]);
        let (_, proof) = vrf::prove(&sk, &[tag]);
        StoredFragment {
            chash: Hash256::of(&[tag]),
            frag: Fragment { index: tag as u64, chunk_len: 100, payload: vec![tag; 64] },
            proof,
            expires_ms: 12345,
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("vault-store-test-{tag}-{}", util::now_ms()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn put_get_remove_roundtrip() {
        let store = DiskStore::open(tmpdir("rt")).unwrap();
        let r = rec(1);
        store.put(&r).unwrap();
        assert_eq!(store.get(&r.chash), Some(r.clone()));
        assert!(store.remove(&r.chash).unwrap());
        assert_eq!(store.get(&r.chash), None);
        assert!(!store.remove(&r.chash).unwrap());
    }

    #[test]
    fn load_all_recovers_everything() {
        let store = DiskStore::open(tmpdir("all")).unwrap();
        for t in 1..=5 {
            store.put(&rec(t)).unwrap();
        }
        let mut all = store.load_all().unwrap();
        all.sort_by_key(|r| r.frag.index);
        assert_eq!(all.len(), 5);
        assert_eq!(all[0], rec(1));
    }

    #[test]
    fn corrupt_files_are_skipped() {
        let dir = tmpdir("corrupt");
        let store = DiskStore::open(&dir).unwrap();
        store.put(&rec(2)).unwrap();
        std::fs::write(dir.join("garbage.frag"), b"not a fragment").unwrap();
        let all = store.load_all().unwrap();
        assert_eq!(all.len(), 1);
    }

    #[test]
    fn burst_of_puts_leaves_no_temp_files() {
        // Same-millisecond writes used to collide on a wall-clock temp
        // name; the hash+counter name must keep every record intact and
        // leave nothing behind.
        let dir = tmpdir("burst");
        let store = DiskStore::open(&dir).unwrap();
        for t in 1..=20 {
            store.put(&rec(t)).unwrap();
        }
        assert_eq!(store.load_all().unwrap().len(), 20);
        let leftovers = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with(".tmp-"))
            .count();
        assert_eq!(leftovers, 0, "temp files must all be renamed away");
    }

    #[test]
    fn overwrite_is_atomic_replacement() {
        let store = DiskStore::open(tmpdir("ow")).unwrap();
        let mut r = rec(3);
        store.put(&r).unwrap();
        r.expires_ms = 999;
        store.put(&r).unwrap();
        assert_eq!(store.get(&r.chash).unwrap().expires_ms, 999);
        assert_eq!(store.load_all().unwrap().len(), 1);
    }
}
