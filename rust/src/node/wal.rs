//! Event-sourced write-ahead log for node-local durable state (ISSUE 6).
//!
//! Every mutation a node must survive a reboot with — fragment admission
//! and retirement, group-membership snapshots, the chain watcher's epoch
//! cursor — is appended as a sequenced, checksummed operation record.
//! Recovery replays the log front-to-back and *materializes* the final
//! state (last-write-wins per chunk), the otters pattern: the log is the
//! source of truth, the in-memory maps are a cache.
//!
//! ## Frame format
//!
//! ```text
//! | len: u32 LE | payload: len bytes | fnv64(payload): u64 LE |
//! ```
//!
//! where `payload` is the wire-encoded [`WalRecord`] (sequence number,
//! timestamp, operation). Replay stops at the first frame that is torn
//! (truncated mid-frame), fails its checksum, fails strict wire decode,
//! or breaks the sequence chain — everything before that point is the
//! *valid prefix* and is fully trusted; everything after is counted and
//! discarded. A torn final write therefore loses exactly the records it
//! overlapped, never earlier ones, and never panics.
//!
//! The simulated runtimes keep the log as an in-memory byte buffer (the
//! sim's "disk": it survives a peer kill inside the slot and is handed
//! to the rebuilt peer at restart, optionally truncated to model a torn
//! tail). [`DiskWal`] backs the same frame format with a real
//! append-only file for the on-disk deployment path.

use std::path::{Path, PathBuf};

use crate::crypto::Hash256;
use crate::dht::PeerInfo;
use crate::wire::{Decode, Encode, Reader, WireError, WireResult, Writer};

use super::storage::StoredFragment;

/// Upper bound on a single frame payload. A `FragPut` carries one
/// fragment (chunk-sized at most); anything claiming to be larger is a
/// corrupt length field, not a real record.
pub const WAL_MAX_FRAME: usize = 1 << 22;

/// FNV-1a 64-bit — the per-record integrity checksum. Not
/// collision-resistant against an adversary (the WAL is node-local and
/// never crosses the network); it only needs to catch torn writes and
/// bit rot, and it is cheap enough to run on every append.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One logged operation — the event vocabulary of the durable state.
#[derive(Clone, Debug, PartialEq)]
pub enum WalOp {
    /// Fragment admitted (store, repair join, or rotation re-proof).
    FragPut(StoredFragment),
    /// Fragment dropped (expiry, grace retirement, explicit remove).
    FragRemove(Hash256),
    /// Full membership snapshot for one chunk group. Snapshots rather
    /// than per-member deltas: a group is ~R entries, and last-write-
    /// wins snapshots make replay order-insensitive within a group.
    Members { chash: Hash256, members: Vec<PeerInfo> },
    /// The chain watcher's cursor: last adopted epoch head. Recovery
    /// adopts the newest cursor, then catches up any missed epochs
    /// through the non-consecutive gap path.
    EpochCursor { epoch: u64, beacon: [u8; 32], n_nodes: u64 },
}

impl Encode for WalOp {
    fn encode(&self, w: &mut Writer) {
        match self {
            WalOp::FragPut(rec) => {
                w.u8(1);
                rec.encode(w);
            }
            WalOp::FragRemove(chash) => {
                w.u8(2);
                chash.encode(w);
            }
            WalOp::Members { chash, members } => {
                w.u8(3);
                chash.encode(w);
                members.encode(w);
            }
            WalOp::EpochCursor { epoch, beacon, n_nodes } => {
                w.u8(4);
                w.u64(*epoch);
                beacon.encode(w);
                w.u64(*n_nodes);
            }
        }
    }
}

impl Decode for WalOp {
    fn decode(r: &mut Reader<'_>) -> WireResult<Self> {
        Ok(match r.u8()? {
            1 => WalOp::FragPut(StoredFragment::decode(r)?),
            2 => WalOp::FragRemove(Hash256::decode(r)?),
            3 => WalOp::Members {
                chash: Hash256::decode(r)?,
                members: Vec::<PeerInfo>::decode(r)?,
            },
            4 => WalOp::EpochCursor {
                epoch: r.u64()?,
                beacon: <[u8; 32]>::decode(r)?,
                n_nodes: r.u64()?,
            },
            t => return Err(WireError::BadTag(t as u32)),
        })
    }
}

/// One WAL entry: a sequence number (dense, starting at 0), the
/// simulated wall clock at append time, and the operation.
#[derive(Clone, Debug, PartialEq)]
pub struct WalRecord {
    pub sequence: u64,
    pub at_ms: u64,
    pub op: WalOp,
}

crate::wire_struct!(WalRecord { sequence, at_ms, op });

/// What replay observed — restart scenarios and the recovery metrics
/// assert on these counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WalReplayReport {
    /// Records in the valid prefix (fully replayed).
    pub replayed: u64,
    /// Frames rejected for checksum / decode / sequence-chain failure
    /// (0 or 1: replay stops at the first bad frame).
    pub corrupt_records: u64,
    /// Bytes beyond the valid prefix (torn tail + anything after it).
    pub torn_tail_bytes: u64,
    /// Length of the valid prefix — recovery resumes appending here.
    pub valid_bytes: u64,
    /// Byte offset where the final replayed frame begins (equals
    /// `valid_bytes` when the log is empty). Lets a torn-write injector
    /// aim its cut at the tail record specifically.
    pub tail_record_offset: u64,
}

/// In-memory append-only WAL buffer — the simulated runtimes' "disk".
#[derive(Clone, Debug, Default)]
pub struct Wal {
    buf: Vec<u8>,
    next_seq: u64,
    last_frame_start: usize,
}

impl Wal {
    pub fn new() -> Wal {
        Wal::default()
    }

    /// Append one operation; returns its sequence number.
    pub fn append(&mut self, at_ms: u64, op: WalOp) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        let payload = WalRecord { sequence: seq, at_ms, op }.to_bytes();
        self.last_frame_start = self.buf.len();
        self.buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        self.buf.extend_from_slice(&payload);
        self.buf.extend_from_slice(&fnv64(&payload).to_le_bytes());
        seq
    }

    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn len_bytes(&self) -> u64 {
        self.buf.len() as u64
    }

    pub fn next_sequence(&self) -> u64 {
        self.next_seq
    }

    /// `[start, end)` byte span of the final frame — the torn-write
    /// injector cuts at a byte inside this span so the tear lands on
    /// the tail record (a cut before it would also drop intact frames,
    /// which models a lost disk, not a torn write).
    pub fn tail_span(&self) -> (u64, u64) {
        (self.last_frame_start as u64, self.buf.len() as u64)
    }

    /// Harvest the raw log, leaving this instance empty (the old peer
    /// object is about to be discarded by the restart hook).
    pub fn take_bytes(&mut self) -> Vec<u8> {
        self.last_frame_start = 0;
        self.next_seq = 0;
        std::mem::take(&mut self.buf)
    }

    /// Rebuild a writer from a crashed node's log: replay, truncate to
    /// the valid prefix, and resume the sequence chain after the last
    /// good record. Returns the records to materialize plus the replay
    /// report.
    pub fn resume(mut buf: Vec<u8>) -> (Wal, Vec<WalRecord>, WalReplayReport) {
        let (records, report) = replay(&buf);
        buf.truncate(report.valid_bytes as usize);
        let wal = Wal {
            buf,
            next_seq: records.last().map(|r| r.sequence + 1).unwrap_or(0),
            last_frame_start: report.tail_record_offset as usize,
        };
        (wal, records, report)
    }
}

/// Decode every valid frame from the front; stop at the first torn,
/// corrupt, or out-of-sequence frame. Never panics on arbitrary bytes.
pub fn replay(bytes: &[u8]) -> (Vec<WalRecord>, WalReplayReport) {
    let mut records = Vec::new();
    let mut report = WalReplayReport::default();
    let mut pos = 0usize;
    let mut expect_seq = 0u64;
    loop {
        let rest = bytes.len() - pos;
        if rest == 0 {
            break;
        }
        if rest < 4 {
            report.torn_tail_bytes = rest as u64;
            break;
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        if len > WAL_MAX_FRAME || rest < 4 + len + 8 {
            // Absurd length = corrupt length field; short frame = torn
            // tail. Either way nothing past here is trustworthy.
            if len > WAL_MAX_FRAME {
                report.corrupt_records += 1;
            }
            report.torn_tail_bytes = rest as u64;
            break;
        }
        let payload = &bytes[pos + 4..pos + 4 + len];
        let sum =
            u64::from_le_bytes(bytes[pos + 4 + len..pos + 4 + len + 8].try_into().unwrap());
        if fnv64(payload) != sum {
            report.corrupt_records += 1;
            report.torn_tail_bytes = rest as u64;
            break;
        }
        let rec = match WalRecord::from_bytes(payload) {
            Ok(rec) if rec.sequence == expect_seq => rec,
            _ => {
                report.corrupt_records += 1;
                report.torn_tail_bytes = rest as u64;
                break;
            }
        };
        expect_seq = rec.sequence + 1;
        report.tail_record_offset = pos as u64;
        pos += 4 + len + 8;
        report.valid_bytes = pos as u64;
        report.replayed += 1;
        records.push(rec);
    }
    (records, report)
}

/// Materialized view of a replayed log: the state a node reboots into.
#[derive(Clone, Debug, Default)]
pub struct WalState {
    /// Surviving fragments with their last snapshotted group view, in
    /// chunk-hash order (a deterministic recovery install order).
    pub fragments: Vec<(StoredFragment, Vec<PeerInfo>)>,
    /// Newest `(epoch, beacon, n_nodes)` cursor, if any was logged.
    pub epoch: Option<(u64, [u8; 32], u64)>,
}

/// Fold records front-to-back, last-write-wins per chunk.
pub fn materialize(records: &[WalRecord]) -> WalState {
    use std::collections::BTreeMap;
    let mut frags: BTreeMap<Hash256, (StoredFragment, Vec<PeerInfo>)> = BTreeMap::new();
    let mut epoch = None;
    for rec in records {
        match &rec.op {
            WalOp::FragPut(sf) => {
                frags.insert(sf.chash, (sf.clone(), Vec::new()));
            }
            WalOp::FragRemove(chash) => {
                frags.remove(chash);
            }
            WalOp::Members { chash, members } => {
                // A snapshot for a chunk we no longer hold is a stale
                // straggler (remove won the race) — ignore it.
                if let Some(entry) = frags.get_mut(chash) {
                    entry.1 = members.clone();
                }
            }
            WalOp::EpochCursor { epoch: e, beacon, n_nodes } => {
                epoch = Some((*e, *beacon, *n_nodes));
            }
        }
    }
    WalState { fragments: frags.into_values().collect(), epoch }
}

/// File-backed WAL for the on-disk deployment path: the same frame
/// format appended to `<path>`, fsynced per record, with the parent
/// directory fsynced on creation so the log file itself survives a
/// crash right after `open`.
pub struct DiskWal {
    file: std::fs::File,
    path: PathBuf,
    next_seq: u64,
}

impl DiskWal {
    /// Open (creating if absent), replay what is on disk, and truncate
    /// the file to the valid prefix so a torn tail is physically
    /// discarded before new appends land after it.
    pub fn open(
        path: impl Into<PathBuf>,
    ) -> std::io::Result<(DiskWal, Vec<WalRecord>, WalReplayReport)> {
        let path = path.into();
        let existed = path.exists();
        let bytes = if existed { std::fs::read(&path)? } else { Vec::new() };
        let (records, report) = replay(&bytes);
        let file = std::fs::OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(&path)?;
        file.set_len(report.valid_bytes)?;
        file.sync_all()?;
        if !existed {
            if let Some(dir) = path.parent() {
                fsync_dir(dir)?;
            }
        }
        let next_seq = records.last().map(|r| r.sequence + 1).unwrap_or(0);
        Ok((DiskWal { file, path, next_seq }, records, report))
    }

    /// Append one record and fsync it to the platter.
    pub fn append(&mut self, at_ms: u64, op: WalOp) -> std::io::Result<u64> {
        use std::io::{Seek, SeekFrom, Write};
        let seq = self.next_seq;
        let payload = WalRecord { sequence: seq, at_ms, op }.to_bytes();
        let mut frame = Vec::with_capacity(payload.len() + 12);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        frame.extend_from_slice(&fnv64(&payload).to_le_bytes());
        self.file.seek(SeekFrom::End(0))?;
        self.file.write_all(&frame)?;
        self.file.sync_data()?;
        self.next_seq = seq + 1;
        Ok(seq)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Fsync a directory handle — makes a rename/create in that directory
/// durable. On non-unix hosts directories cannot be opened as files;
/// there the call is a no-op (the sim never exercises it anyway).
pub fn fsync_dir(dir: &Path) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        std::fs::File::open(dir)?.sync_all()
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::rateless::Fragment;
    use crate::crypto::ed25519::SigningKey;
    use crate::crypto::vrf;

    fn frag_rec(tag: u8) -> StoredFragment {
        let sk = SigningKey::from_seed(&[tag; 32]);
        let (_, proof) = vrf::prove(&sk, &[tag]);
        StoredFragment {
            chash: Hash256::of(&[tag]),
            frag: Fragment { index: tag as u64, chunk_len: 80, payload: vec![tag; 48] },
            proof,
            expires_ms: 0,
        }
    }

    fn peer_info(tag: u8) -> PeerInfo {
        let sk = SigningKey::from_seed(&[tag ^ 0x5A; 32]);
        PeerInfo {
            id: crate::dht::NodeId::from_pk(&sk.public),
            pk: sk.public,
            region: tag % 5,
        }
    }

    fn sample_wal() -> Wal {
        let mut wal = Wal::new();
        wal.append(10, WalOp::FragPut(frag_rec(1)));
        wal.append(10, WalOp::Members {
            chash: frag_rec(1).chash,
            members: vec![peer_info(1), peer_info(2)],
        });
        wal.append(20, WalOp::FragPut(frag_rec(2)));
        wal.append(30, WalOp::EpochCursor { epoch: 7, beacon: [9; 32], n_nodes: 64 });
        wal.append(40, WalOp::FragRemove(frag_rec(2).chash));
        wal
    }

    #[test]
    fn replay_roundtrips_and_materializes() {
        let wal = sample_wal();
        let (records, report) = replay(wal.bytes());
        assert_eq!(report.replayed, 5);
        assert_eq!(report.corrupt_records, 0);
        assert_eq!(report.torn_tail_bytes, 0);
        assert_eq!(report.valid_bytes, wal.len_bytes());
        assert_eq!(records.len(), 5);

        let state = materialize(&records);
        assert_eq!(state.fragments.len(), 1, "put+remove must cancel for chunk 2");
        assert_eq!(state.fragments[0].0, frag_rec(1));
        assert_eq!(state.fragments[0].1, vec![peer_info(1), peer_info(2)]);
        assert_eq!(state.epoch, Some((7, [9; 32], 64)));
    }

    #[test]
    fn torn_tail_at_every_byte_loses_only_the_tail() {
        // Truncate the log at EVERY byte prefix: replay must never
        // panic, must keep every frame wholly before the cut, and must
        // report the tear.
        let wal = sample_wal();
        let bytes = wal.bytes();
        let (full, _) = replay(bytes);
        for cut in 0..bytes.len() {
            let (records, report) = replay(&bytes[..cut]);
            assert!(records.len() <= full.len());
            assert_eq!(records, full[..records.len()], "prefix must replay identically");
            assert_eq!(
                report.valid_bytes as usize + report.torn_tail_bytes as usize,
                cut,
                "every byte is either valid prefix or torn tail (cut={cut})"
            );
            if (cut as u64) < wal.len_bytes() {
                assert!(records.len() < full.len(), "a cut mid-log must lose the tail record");
            }
        }
    }

    #[test]
    fn bit_flip_at_every_byte_is_detected_and_bounded() {
        // Flip one bit at every byte position: replay must reject the
        // damaged frame (checksum or decode) and keep everything before
        // it — corruption never silently yields a different record.
        let wal = sample_wal();
        let clean = wal.bytes().to_vec();
        let (full, _) = replay(&clean);
        for pos in 0..clean.len() {
            let mut dirty = clean.clone();
            dirty[pos] ^= 0x01;
            let (records, report) = replay(&dirty);
            assert!(records.len() < full.len(), "flip at {pos} must lose at least the hit frame");
            assert_eq!(records, full[..records.len()], "frames before the flip must survive");
            assert!(
                report.corrupt_records > 0 || report.torn_tail_bytes > 0,
                "flip at {pos} must be reported"
            );
        }
    }

    #[test]
    fn sequence_break_stops_replay() {
        // Two independent logs concatenated restart the sequence chain
        // at 0 — replay must refuse the second log's records.
        let wal = sample_wal();
        let mut spliced = wal.bytes().to_vec();
        spliced.extend_from_slice(sample_wal().bytes());
        let (records, report) = replay(&spliced);
        assert_eq!(records.len(), 5);
        assert_eq!(report.corrupt_records, 1);
    }

    #[test]
    fn resume_continues_the_sequence_chain() {
        let wal = sample_wal();
        let (mut resumed, records, report) = Wal::resume(wal.bytes().to_vec());
        assert_eq!(records.len(), 5);
        assert_eq!(report.replayed, 5);
        assert_eq!(resumed.next_sequence(), 5);
        let seq = resumed.append(50, WalOp::FragRemove(frag_rec(1).chash));
        assert_eq!(seq, 5);
        let (records2, report2) = replay(resumed.bytes());
        assert_eq!(report2.corrupt_records, 0);
        assert_eq!(records2.len(), 6);
        assert!(materialize(&records2).fragments.is_empty());
    }

    #[test]
    fn tail_span_brackets_the_last_frame() {
        let wal = sample_wal();
        let (start, end) = wal.tail_span();
        assert!(start < end);
        assert_eq!(end, wal.len_bytes());
        // A cut inside the span loses exactly the tail record.
        let (records, _) = replay(&wal.bytes()[..start as usize + 1]);
        assert_eq!(records.len(), 4);
    }

    #[test]
    fn disk_wal_survives_reopen_and_truncates_torn_tail() {
        let dir = std::env::temp_dir()
            .join(format!("vault-wal-test-{}", crate::util::now_ms()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wal.log");

        let (mut dw, records, _) = DiskWal::open(&path).unwrap();
        assert!(records.is_empty());
        dw.append(10, WalOp::FragPut(frag_rec(3))).unwrap();
        dw.append(20, WalOp::EpochCursor { epoch: 2, beacon: [1; 32], n_nodes: 10 }).unwrap();
        drop(dw);

        // Clean reopen replays both records.
        let (dw, records, report) = DiskWal::open(&path).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(report.torn_tail_bytes, 0);
        drop(dw);

        // Tear the tail record mid-frame; reopen must drop exactly it,
        // truncate the file back to the valid prefix, and resume the
        // sequence chain at the lost record's number.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let (mut dw, records, report) = DiskWal::open(&path).unwrap();
        assert_eq!(records.len(), 1);
        assert!(report.torn_tail_bytes > 0);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), report.valid_bytes);
        let seq = dw.append(30, WalOp::FragRemove(frag_rec(3).chash)).unwrap();
        assert_eq!(seq, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
