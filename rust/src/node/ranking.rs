//! Read-path client state (ISSUE 10): latency-aware replica ranking,
//! the hedged-request trigger/budget, and the client-side chunk cache.
//!
//! [`ReplicaRanker`] scores each peer by a decayed EWMA of observed
//! request latencies (integer fixed-point — no floats, no RNG, so
//! enabling it perturbs no other consumer's draw sequence and stays
//! deterministic across platforms). It also keeps a bounded ring of
//! recent latency samples whose nearest-rank quantile drives the hedge
//! delay, and the milli-token budget that bounds hedge amplification.
//!
//! [`ReadCache`] is a byte-bounded CLOCK cache over decoded chunks.
//! Entries never expire by time; the owning peer invalidates the whole
//! cache at every adopted epoch rotation (placement moved, so every
//! cached chunk predates the boundary — see DESIGN.md §Read Path for
//! the invalidation-ordering contract).

use crate::crypto::Hash256;
use crate::dht::NodeId;
use crate::util::detmap::DetHashMap;

/// EWMA fixed-point scale: scores are milliseconds × 16.
const EWMA_SCALE: u64 = 16;

/// Cost of one per-chunk hedge wave, in milli-tokens.
pub const HEDGE_WAVE_COST: u64 = 1_000;

/// Latency-ranking state one client peer owns (when
/// `VaultConfig::read_ranking` or `read_hedge` is on).
#[derive(Clone, Debug)]
pub struct ReplicaRanker {
    /// Prior score (fixed-point) for peers never observed — ranks them
    /// behind every observed-fast peer but ahead of observed-slow ones.
    prior: u64,
    /// Decayed latency per peer, fixed-point ms×16, alpha = 1/4.
    ewma: DetHashMap<NodeId, u64>,
    /// Outstanding asks: `(op, peer) -> sent_ms` (the ranker tracks its
    /// own sends so it works with the health plane off).
    pending: DetHashMap<(u64, NodeId), u64>,
    /// Bounded ring of recent latency samples (ms) for the hedge
    /// quantile.
    ring: Vec<u64>,
    ring_cap: usize,
    ring_at: usize,
    /// Hedge amplification budget, milli-tokens.
    mtokens: u64,
    mtokens_cap: u64,
}

impl ReplicaRanker {
    pub fn new(prior_ms: u64, budget_cap_mtokens: u64, ring_cap: usize) -> Self {
        ReplicaRanker {
            prior: prior_ms.max(1) * EWMA_SCALE,
            ewma: DetHashMap::default(),
            pending: DetHashMap::default(),
            ring: Vec::new(),
            ring_cap: ring_cap.max(1),
            ring_at: 0,
            mtokens: budget_cap_mtokens,
            mtokens_cap: budget_cap_mtokens,
        }
    }

    /// Register an outbound request `peer` is expected to answer.
    pub fn track(&mut self, op: u64, peer: NodeId, now_ms: u64) {
        self.pending.insert((op, peer), now_ms);
    }

    /// A reply arrived: fold the measured latency into the peer's EWMA
    /// and the quantile ring. Untracked replies are ignored.
    pub fn observe(&mut self, op: u64, peer: NodeId, now_ms: u64) -> Option<u64> {
        let sent = self.pending.remove(&(op, peer))?;
        let sample_ms = now_ms.saturating_sub(sent);
        let fp = sample_ms * EWMA_SCALE;
        let e = self.ewma.entry(peer).or_insert(fp);
        // alpha = 1/4: e' = 3/4·e + 1/4·sample (integer, deterministic).
        *e = (*e * 3 + fp) / 4;
        if self.ring.len() < self.ring_cap {
            self.ring.push(sample_ms);
        } else {
            self.ring[self.ring_at] = sample_ms;
            self.ring_at = (self.ring_at + 1) % self.ring_cap;
        }
        Some(sample_ms)
    }

    /// Drop tracking for a finished/cancelled op without recording
    /// samples (stragglers may still answer; their latency would be
    /// the saga's lifetime, not the peer's).
    pub fn forget_op(&mut self, op: u64) {
        self.pending.retain(|(o, _), _| *o != op);
    }

    /// Fixed-point score: observed EWMA, or the prior for strangers.
    pub fn score(&self, peer: &NodeId) -> u64 {
        self.ewma.get(peer).copied().unwrap_or(self.prior)
    }

    /// Stable-sort `items` fastest-first by score; ties (and all-prior
    /// lists) keep their incoming ring-distance order.
    pub fn rank<T, F: Fn(&T) -> NodeId>(&self, items: &mut [T], id_of: F) {
        if self.ewma.is_empty() {
            return;
        }
        items.sort_by_key(|it| self.score(&id_of(it)));
    }

    /// Hedge-trigger delay: the `pct` nearest-rank quantile of the
    /// recent-latency ring, clamped to `[timeout/32, timeout/2]`; with
    /// no samples yet, `timeout/8`.
    pub fn hedge_delay_ms(&self, pct: u64, timeout_ms: u64) -> u64 {
        let lo = (timeout_ms / 32).max(1);
        let hi = (timeout_ms / 2).max(1);
        if self.ring.is_empty() {
            return (timeout_ms / 8).clamp(lo, hi);
        }
        let mut sorted = self.ring.clone();
        sorted.sort_unstable();
        let pct = pct.clamp(1, 100) as usize;
        let rank = (pct * sorted.len()).div_ceil(100).max(1);
        sorted[rank - 1].clamp(lo, hi)
    }

    /// Earn refill tokens (one helping per submitted query), capped.
    pub fn earn(&mut self, amount: u64) {
        self.mtokens = (self.mtokens + amount).min(self.mtokens_cap);
    }

    /// Can a wave of `cost` milli-tokens be afforded right now?
    pub fn can_spend(&self, cost: u64) -> bool {
        self.mtokens >= cost
    }

    pub fn spend(&mut self, cost: u64) {
        self.mtokens = self.mtokens.saturating_sub(cost);
    }

    pub fn budget_mtokens(&self) -> u64 {
        self.mtokens
    }
}

/// One CLOCK slot: a decoded chunk plus its reference bit.
#[derive(Clone, Debug)]
struct CacheEntry {
    chash: Hash256,
    bytes: Vec<u8>,
    referenced: bool,
}

/// Byte-bounded client-side cache of decoded chunks, CLOCK eviction.
#[derive(Clone, Debug, Default)]
pub struct ReadCache {
    cap_bytes: usize,
    used_bytes: usize,
    entries: Vec<CacheEntry>,
    hand: usize,
    index: DetHashMap<Hash256, usize>,
}

impl ReadCache {
    pub fn new(cap_bytes: usize) -> Self {
        ReadCache { cap_bytes, ..Default::default() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    /// Cache lookup; a hit sets the reference bit (second-chance).
    pub fn get(&mut self, chash: &Hash256) -> Option<&[u8]> {
        let &i = self.index.get(chash)?;
        self.entries[i].referenced = true;
        Some(&self.entries[i].bytes)
    }

    /// Insert a decoded chunk, evicting via the CLOCK hand until it
    /// fits. Oversize chunks (bigger than the whole cache) and
    /// duplicates are no-ops.
    pub fn insert(&mut self, chash: Hash256, bytes: Vec<u8>) {
        if bytes.len() > self.cap_bytes || self.index.contains_key(&chash) {
            return;
        }
        while self.used_bytes + bytes.len() > self.cap_bytes && !self.entries.is_empty() {
            self.evict_one();
        }
        self.index.insert(chash, self.entries.len());
        self.used_bytes += bytes.len();
        self.entries.push(CacheEntry { chash, bytes, referenced: false });
    }

    /// Advance the hand, clearing reference bits, until an unreferenced
    /// entry falls out.
    fn evict_one(&mut self) {
        loop {
            if self.hand >= self.entries.len() {
                self.hand = 0;
            }
            if self.entries[self.hand].referenced {
                self.entries[self.hand].referenced = false;
                self.hand += 1;
                continue;
            }
            let e = self.entries.swap_remove(self.hand);
            self.used_bytes -= e.bytes.len();
            self.index.remove(&e.chash);
            // The swapped-in tail entry now lives at `hand`.
            if self.hand < self.entries.len() {
                let moved = self.entries[self.hand].chash;
                self.index.insert(moved, self.hand);
            }
            return;
        }
    }

    /// Rotation boundary: placement moved, so every cached chunk
    /// predates the new epoch. Drop everything; returns how many
    /// entries were invalidated.
    pub fn invalidate_all(&mut self) -> u64 {
        let n = self.entries.len() as u64;
        self.entries.clear();
        self.index.clear();
        self.used_bytes = 0;
        self.hand = 0;
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(tag: u8) -> NodeId {
        NodeId(Hash256::of(&[tag]))
    }

    fn ch(tag: u8) -> Hash256 {
        Hash256::of(&[0xCC, tag])
    }

    #[test]
    fn ranker_orders_by_observed_latency() {
        let mut r = ReplicaRanker::new(150, 8_000, 64);
        let (fast, slow, unknown) = (id(1), id(2), id(3));
        for op in 0..4 {
            r.track(op, fast, 0);
            r.observe(op, fast, 20);
            r.track(op, slow, 0);
            r.observe(op, slow, 2_000);
        }
        let mut v = vec![slow, unknown, fast];
        r.rank(&mut v, |x| *x);
        assert_eq!(v, vec![fast, unknown, slow], "fast < prior < slow");
        assert!(r.score(&fast) < r.score(&unknown));
        assert!(r.score(&unknown) < r.score(&slow));
    }

    #[test]
    fn rank_without_observations_preserves_order() {
        let r = ReplicaRanker::new(150, 0, 8);
        let mut v = vec![id(3), id(1), id(2)];
        r.rank(&mut v, |x| *x);
        assert_eq!(v, vec![id(3), id(1), id(2)]);
    }

    #[test]
    fn ewma_decays_toward_recent_samples() {
        let mut r = ReplicaRanker::new(150, 0, 64);
        let p = id(7);
        r.track(1, p, 0);
        r.observe(1, p, 1_000);
        let slow_score = r.score(&p);
        for op in 2..10 {
            r.track(op, p, 0);
            r.observe(op, p, 10);
        }
        assert!(r.score(&p) < slow_score / 4, "recent fast samples dominate");
    }

    #[test]
    fn untracked_and_forgotten_replies_are_ignored() {
        let mut r = ReplicaRanker::new(150, 0, 8);
        assert_eq!(r.observe(9, id(1), 100), None);
        r.track(9, id(1), 0);
        r.forget_op(9);
        assert_eq!(r.observe(9, id(1), 100), None);
        assert!(r.ring.is_empty());
    }

    #[test]
    fn hedge_delay_tracks_the_quantile_and_clamps() {
        let mut r = ReplicaRanker::new(150, 0, 64);
        // No samples: timeout/8 default.
        assert_eq!(r.hedge_delay_ms(90, 3_000), 375);
        for (i, ms) in [100u64, 200, 300, 400, 500, 600, 700, 800, 900, 1_000]
            .iter()
            .enumerate()
        {
            r.track(i as u64, id(1), 0);
            r.observe(i as u64, id(1), *ms);
        }
        assert_eq!(r.hedge_delay_ms(90, 3_000), 900, "p90 of 100..=1000");
        assert_eq!(r.hedge_delay_ms(50, 3_000), 500);
        // Clamp floor and ceiling.
        assert_eq!(r.hedge_delay_ms(1, 3_000), 100.max(3_000 / 32));
        assert_eq!(r.hedge_delay_ms(100, 1_000), 500, "capped at timeout/2");
    }

    #[test]
    fn ring_is_bounded() {
        let mut r = ReplicaRanker::new(150, 0, 4);
        for op in 0..20 {
            r.track(op, id(1), 0);
            r.observe(op, id(1), op * 10);
        }
        assert_eq!(r.ring.len(), 4);
    }

    #[test]
    fn budget_spends_and_refills_to_cap() {
        let mut r = ReplicaRanker::new(150, 2_500, 8);
        assert!(r.can_spend(HEDGE_WAVE_COST));
        r.spend(HEDGE_WAVE_COST);
        r.spend(HEDGE_WAVE_COST);
        assert_eq!(r.budget_mtokens(), 500);
        assert!(!r.can_spend(HEDGE_WAVE_COST));
        r.earn(10_000);
        assert_eq!(r.budget_mtokens(), 2_500, "refill caps at the budget");
    }

    #[test]
    fn cache_bounds_bytes_and_clock_prefers_referenced() {
        let mut c = ReadCache::new(100);
        c.insert(ch(1), vec![0; 40]);
        c.insert(ch(2), vec![0; 40]);
        assert_eq!(c.used_bytes(), 80);
        // Touch entry 1 so its reference bit protects it.
        assert!(c.get(&ch(1)).is_some());
        c.insert(ch(3), vec![0; 40]);
        assert!(c.used_bytes() <= 100);
        assert!(c.get(&ch(1)).is_some(), "referenced entry survives");
        assert!(c.get(&ch(2)).is_none(), "unreferenced entry evicted");
        assert!(c.get(&ch(3)).is_some());
    }

    #[test]
    fn cache_rejects_oversize_and_duplicates() {
        let mut c = ReadCache::new(50);
        c.insert(ch(1), vec![0; 60]);
        assert!(c.is_empty(), "oversize insert is a no-op");
        c.insert(ch(2), vec![1; 20]);
        c.insert(ch(2), vec![2; 20]);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&ch(2)).unwrap(), &[1u8; 20][..], "first insert wins");
    }

    #[test]
    fn invalidate_all_clears_everything() {
        let mut c = ReadCache::new(1_000);
        c.insert(ch(1), vec![0; 10]);
        c.insert(ch(2), vec![0; 10]);
        assert_eq!(c.invalidate_all(), 2);
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
        assert!(c.get(&ch(1)).is_none());
        // Still usable after invalidation.
        c.insert(ch(3), vec![0; 10]);
        assert!(c.get(&ch(3)).is_some());
    }
}
