//! Peer-health defense layer (ISSUE 8).
//!
//! Per-peer request tracking with deadlines, a decayed misbehavior
//! score fed by timeouts / undecodable garbage / oversize payloads /
//! slow-trickle responses, greylisting, and network-wide quarantine on
//! verified equivocation evidence.
//!
//! Semantics that keep this a *defense* and not a new partition vector:
//!
//! * **Greylist = deprioritize, never refuse.** A greylisted peer is
//!   sorted to the back of query fan-out candidate lists and repair
//!   probe sets and is excluded from DHT bucket refills, but it is
//!   still *served* (reads, joins, audits) and still counted as a
//!   group member — graceful degradation under suspicion, full service
//!   on recovery. Scores decay every tick, so a peer that stops
//!   misbehaving (or was briefly unlucky) clears automatically.
//! * **Quarantine is evidence-gated.** Only a self-contained
//!   cryptographic proof (`chain::EquivocationEvidence`) quarantines a
//!   peer, and the proof travels with the verdict — one honest
//!   observer convinces everyone, and nobody can be quarantined by
//!   rumor. Quarantined peers are excluded from repair recruitment and
//!   group alive-sets (mirroring audit-suspect eviction) but, again,
//!   never refused service.
//! * **Own RNG stream.** Backoff jitter draws from a dedicated forked
//!   stream, so enabling the health plane perturbs no other consumer's
//!   draw sequence (the flag-off fingerprint guarantee).
//!
//! The scoring model mirrors `audit::ledger`: accumulate weighted
//! offenses, decay multiplicatively each tick, mark at a threshold,
//! clear when decay brings the score back under half the threshold,
//! GC state that reaches zero.

use crate::dht::NodeId;
use crate::util::detmap::{DetHashMap, DetHashSet};
use crate::util::rng::Rng;

/// Score floor below which an entry is considered fully recovered and
/// its state garbage-collected.
const SCORE_FLOOR: f64 = 1e-3;

/// Misbehavior classes feeding the decayed score, in increasing order
/// of "this cannot happen by accident".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Offense {
    /// A tracked request passed its deadline with no reply.
    Timeout,
    /// Reply arrived, but only just under the timeout (slow-loris).
    SlowTrickle,
    /// Undecodable wire bytes from this peer.
    Garbage,
    /// Structurally valid but oversize payload (resource attack).
    Oversize,
}

impl Offense {
    pub fn weight(self) -> f64 {
        match self {
            Offense::Timeout => 1.0,
            Offense::SlowTrickle => 0.75,
            Offense::Garbage => 1.5,
            Offense::Oversize => 1.5,
        }
    }
}

/// Per-peer decayed misbehavior state.
#[derive(Clone, Debug, Default)]
pub struct PeerHealth {
    pub score: f64,
    pub greylisted: bool,
}

/// What an offense did to the peer's standing (for metrics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Standing {
    Ok,
    NewlyGreylisted,
    AlreadyGreylisted,
}

/// The tracker one `VaultPeer` owns (when `VaultConfig::peer_health`
/// is on; with the flag off the peer never constructs one).
#[derive(Clone, Debug)]
pub struct HealthTracker {
    /// Score at which a peer is greylisted.
    threshold: f64,
    /// Per-tick multiplicative decay.
    decay: f64,
    /// Dedicated jitter stream (forked from the peer's RNG at start).
    rng: Rng,
    peers: DetHashMap<NodeId, PeerHealth>,
    quarantined: DetHashSet<NodeId>,
    /// In-flight tracked requests: `(op, responder) -> sent_ms`.
    pending: DetHashMap<(u64, NodeId), u64>,
}

impl HealthTracker {
    pub fn new(threshold: f64, decay: f64, rng: Rng) -> Self {
        HealthTracker {
            threshold,
            decay,
            rng,
            peers: DetHashMap::default(),
            quarantined: DetHashSet::default(),
            pending: DetHashMap::default(),
        }
    }

    /// Register an outbound request we expect `peer` to answer.
    pub fn track(&mut self, op: u64, peer: NodeId, now_ms: u64) {
        self.pending.insert((op, peer), now_ms);
    }

    /// A reply for `(op, peer)` arrived. Returns the offense recorded,
    /// if the response took `slow_after_ms` or longer (slow-trickle).
    /// Untracked replies (duplicates, unsolicited) are ignored.
    pub fn resolve(
        &mut self,
        op: u64,
        peer: NodeId,
        now_ms: u64,
        slow_after_ms: u64,
    ) -> Option<Standing> {
        let sent = self.pending.remove(&(op, peer))?;
        if now_ms.saturating_sub(sent) >= slow_after_ms {
            Some(self.offense(peer, Offense::SlowTrickle))
        } else {
            None
        }
    }

    /// The op's retry timer fired: every responder pending for at
    /// least `min_age_ms` ate its deadline. Returns them (sorted for
    /// determinism) so the caller can record one `Timeout` offense
    /// each. Younger entries — fanned out mid-period, their clock
    /// still running — stay pending, which is what keeps a slow timer
    /// alignment from ever blaming an honest peer prematurely.
    pub fn expire_op(&mut self, op: u64, now_ms: u64, min_age_ms: u64) -> Vec<NodeId> {
        let mut late: Vec<NodeId> = self
            .pending
            .iter()
            .filter(|(&(o, _), &sent)| o == op && now_ms.saturating_sub(sent) >= min_age_ms)
            .map(|(&(_, p), _)| p)
            .collect();
        late.sort();
        for p in &late {
            self.pending.remove(&(op, *p));
        }
        late
    }

    /// Drop tracking for an op without blaming anyone (saga completed;
    /// stragglers may still answer and should not be offenses).
    pub fn forget_op(&mut self, op: u64) {
        self.pending.retain(|(o, _), _| *o != op);
    }

    /// Record a weighted offense; returns the standing transition.
    pub fn offense(&mut self, peer: NodeId, kind: Offense) -> Standing {
        let h = self.peers.entry(peer).or_default();
        h.score += kind.weight();
        if h.greylisted {
            Standing::AlreadyGreylisted
        } else if h.score >= self.threshold {
            h.greylisted = true;
            Standing::NewlyGreylisted
        } else {
            Standing::Ok
        }
    }

    /// Per-tick decay: scores shrink multiplicatively, greylists clear
    /// once the score falls under half the threshold, and fully
    /// recovered entries are GC'd. Returns how many greylists cleared.
    pub fn decay_tick(&mut self) -> u64 {
        let mut cleared = 0;
        let threshold = self.threshold;
        let decay = self.decay;
        self.peers.retain(|_, h| {
            h.score *= decay;
            if h.greylisted && h.score < threshold * 0.5 {
                h.greylisted = false;
                cleared += 1;
            }
            h.score >= SCORE_FLOOR
        });
        cleared
    }

    /// True when a `decay_tick` would be a no-op: no scores to decay and
    /// no tracked requests. Quarantine entries don't matter here — decay
    /// never touches them. The scale runtime's dormancy fast-path
    /// (DESIGN.md §Scale Runtime) uses this to elide maintenance ticks.
    pub fn is_quiescent(&self) -> bool {
        self.peers.is_empty() && self.pending.is_empty()
    }

    pub fn is_greylisted(&self, id: &NodeId) -> bool {
        self.peers.get(id).map(|h| h.greylisted).unwrap_or(false)
    }

    pub fn greylisted_count(&self) -> u64 {
        self.peers.values().filter(|h| h.greylisted).count() as u64
    }

    /// Quarantine on verified equivocation evidence. Returns `true` if
    /// this is new information (gossip should propagate once).
    pub fn quarantine(&mut self, id: NodeId) -> bool {
        self.quarantined.insert(id)
    }

    pub fn is_quarantined(&self, id: &NodeId) -> bool {
        self.quarantined.contains(id)
    }

    pub fn quarantined_count(&self) -> u64 {
        self.quarantined.len() as u64
    }

    /// Capped exponential backoff with deterministic jitter from the
    /// tracker's own stream: `min(base·2^retries, base·2^cap_exp)`
    /// plus up to `base/4` of jitter.
    pub fn backoff_ms(&mut self, base_ms: u64, retries: u32, cap_exp: u32) -> u64 {
        let exp = retries.min(cap_exp);
        let backoff = base_ms.saturating_mul(1u64 << exp);
        let jitter = if base_ms >= 4 { self.rng.below(base_ms / 4) } else { 0 };
        backoff + jitter
    }

    /// Stable-partition `items` so greylisted peers come last, without
    /// disturbing relative order inside either class (the fan-out
    /// still reaches them — after everyone in better standing).
    pub fn deprioritize<T, F: Fn(&T) -> NodeId>(&self, items: &mut Vec<T>, id_of: F) {
        if self.peers.values().all(|h| !h.greylisted) {
            return;
        }
        let mut good = Vec::with_capacity(items.len());
        let mut grey = Vec::new();
        for it in items.drain(..) {
            if self.is_greylisted(&id_of(&it)) {
                grey.push(it);
            } else {
                good.push(it);
            }
        }
        good.extend(grey);
        *items = good;
    }
}

/// Plain capped exponential backoff (no jitter, no RNG) — the
/// flag-independent schedule `JoinRetry` uses when the health plane is
/// off, so the retry-storm bugfix never perturbs legacy RNG streams.
pub fn capped_backoff_ms(base_ms: u64, retries: u32, cap_exp: u32) -> u64 {
    base_ms.saturating_mul(1u64 << retries.min(cap_exp))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(tag: u8) -> NodeId {
        NodeId(crate::crypto::Hash256::of(&[tag]))
    }

    fn tracker() -> HealthTracker {
        HealthTracker::new(3.0, 0.5, Rng::new(7))
    }

    #[test]
    fn offenses_accumulate_to_greylist_and_decay_clears() {
        let mut t = tracker();
        let p = id(1);
        assert_eq!(t.offense(p, Offense::Timeout), Standing::Ok);
        assert_eq!(t.offense(p, Offense::Timeout), Standing::Ok);
        assert_eq!(t.offense(p, Offense::Garbage), Standing::NewlyGreylisted);
        assert!(t.is_greylisted(&p));
        assert_eq!(t.offense(p, Offense::Timeout), Standing::AlreadyGreylisted);
        assert_eq!(t.greylisted_count(), 1);
        // score 4.5 → 2.25 → 1.125 (< 1.5 = threshold/2 ⇒ cleared)
        assert_eq!(t.decay_tick(), 0);
        assert_eq!(t.decay_tick(), 1);
        assert!(!t.is_greylisted(&p));
        // Long quiet: state fully GC'd.
        for _ in 0..40 {
            t.decay_tick();
        }
        assert_eq!(t.greylisted_count(), 0);
        assert!(!t.peers.contains_key(&p));
    }

    #[test]
    fn pending_tracking_blames_only_the_silent() {
        let mut t = tracker();
        let (a, b) = (id(1), id(2));
        t.track(9, a, 1000);
        t.track(9, b, 1000);
        // a answers promptly: no offense.
        assert_eq!(t.resolve(9, a, 1500, 1500), None);
        // duplicate / unsolicited replies are ignored.
        assert_eq!(t.resolve(9, a, 1600, 1500), None);
        // b never answers: expire blames exactly b.
        assert_eq!(t.expire_op(9, 2500, 1500), vec![b]);
        assert!(t.expire_op(9, 2500, 1500).is_empty(), "expiry is idempotent");
    }

    #[test]
    fn expire_spares_requests_younger_than_min_age() {
        let mut t = tracker();
        let (a, b) = (id(1), id(2));
        t.track(3, a, 0); // a full period old at expiry
        t.track(3, b, 900); // fanned out mid-period
        assert_eq!(t.expire_op(3, 1000, 1000), vec![a]);
        // b stays tracked and is blamed only once its own period runs out.
        assert_eq!(t.expire_op(3, 2000, 1000), vec![b]);
    }

    #[test]
    fn slow_trickle_is_an_offense() {
        let mut t = tracker();
        let p = id(3);
        t.track(4, p, 0);
        // Arrived, but at 2900 ms of a 1500 ms slow threshold.
        assert_eq!(t.resolve(4, p, 2900, 1500), Some(Standing::Ok));
        assert!(t.peers[&p].score > 0.0);
    }

    #[test]
    fn forget_op_clears_without_blame() {
        let mut t = tracker();
        let p = id(4);
        t.track(11, p, 0);
        t.forget_op(11);
        assert!(t.expire_op(11, 5000, 0).is_empty());
        assert!(t.peers.get(&p).is_none());
    }

    #[test]
    fn quarantine_is_sticky_and_reports_novelty() {
        let mut t = tracker();
        let p = id(5);
        assert!(!t.is_quarantined(&p));
        assert!(t.quarantine(p), "first evidence is news");
        assert!(!t.quarantine(p), "repeat evidence is not");
        assert!(t.is_quarantined(&p));
        for _ in 0..10 {
            t.decay_tick();
        }
        assert!(t.is_quarantined(&p), "decay never lifts quarantine");
    }

    #[test]
    fn backoff_grows_and_caps() {
        let mut t = tracker();
        let base = 1000;
        let b0 = t.backoff_ms(base, 0, 3);
        let b3 = t.backoff_ms(base, 3, 3);
        let b9 = t.backoff_ms(base, 9, 3);
        assert!((base..base + 250).contains(&b0));
        assert!((8 * base..8 * base + 250).contains(&b3));
        assert!((8 * base..8 * base + 250).contains(&b9), "capped at 2^3");
        assert_eq!(capped_backoff_ms(base, 0, 3), base);
        assert_eq!(capped_backoff_ms(base, 2, 3), 4 * base);
        assert_eq!(capped_backoff_ms(base, 9, 3), 8 * base);
    }

    #[test]
    fn deprioritize_is_a_stable_partition() {
        let mut t = tracker();
        for _ in 0..4 {
            t.offense(id(2), Offense::Garbage);
        }
        assert!(t.is_greylisted(&id(2)));
        let mut v = vec![id(1), id(2), id(3), id(4)];
        t.deprioritize(&mut v, |x| *x);
        assert_eq!(v, vec![id(1), id(3), id(4), id(2)]);
    }
}
