//! DHT substrate: node identity, ring distance, routing tables and
//! iterative Kademlia-style lookup.
//!
//! VAULT "uses a distributed hash table, but mainly for its routing and
//! peer lookup functionality" (§4.1) with weak assumptions: lookups are
//! best-effort and return peers close to a hash with high probability.
//! Node IDs are `SHA256(pk)` so they are uniformly distributed on the
//! ring (§4.3) — that uniformity is what makes chunk groups
//! hypergeometric samples of the population (Appendix A).

pub mod kademlia;
pub mod routing;

use crate::crypto::Hash256;
use crate::wire::{Decode, Encode, Reader, WireResult, Writer};

/// Node identity = SHA-256 of the node's Ed25519 public key.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct NodeId(pub Hash256);

impl NodeId {
    pub fn from_pk(pk: &[u8; 32]) -> NodeId {
        NodeId(Hash256::of(pk))
    }
    pub fn short(&self) -> String {
        self.0.short()
    }
}

impl std::fmt::Debug for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "NodeId({}..)", self.short())
    }
}

impl Encode for NodeId {
    fn encode(&self, w: &mut Writer) {
        self.0.encode(w);
    }
}
impl Decode for NodeId {
    fn decode(r: &mut Reader<'_>) -> WireResult<Self> {
        Ok(NodeId(Hash256::decode(r)?))
    }
}

/// Contact info advertised through the DHT.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PeerInfo {
    pub id: NodeId,
    pub pk: [u8; 32],
    /// Region index (0..NUM_REGIONS) — simnet latency class.
    pub region: u8,
}

crate::wire_struct!(PeerInfo { id, pk, region });

/// Circular distance between two points on the 2^128-normalized ring
/// (we fold 256-bit hashes to their top 128 bits; the fold preserves
/// uniformity and makes distance arithmetic cheap).
pub fn ring_distance(a: &Hash256, b: &Hash256) -> u128 {
    let x = a.prefix_u128();
    let y = b.prefix_u128();
    let d = x.wrapping_sub(y);
    let d2 = y.wrapping_sub(x);
    d.min(d2)
}

/// Paper Algorithm 2 `Distance`: distance expressed in expected numbers
/// of nodes between the two points, 1-based: `|a-b| / (2^hashlen / N) + 1`.
pub fn rank_distance(a: &Hash256, b: &Hash256, n_nodes: usize) -> f64 {
    let d = ring_distance(a, b) as f64;
    let spacing = (u128::MAX as f64 + 1.0) / (n_nodes.max(1) as f64);
    // Ring distance counts one direction only; expected #nodes within
    // circular distance d of the target is 2d/spacing.
    2.0 * d / spacing + 1.0
}

/// XOR distance (Kademlia metric) — used for routing, not selection.
pub fn xor_distance(a: &NodeId, b: &Hash256) -> Hash256 {
    a.0.xor(b)
}

/// Sort peers by ring distance to `target` (nearest first).
pub fn sort_by_ring_distance(peers: &mut [PeerInfo], target: &Hash256) {
    peers.sort_by_key(|p| ring_distance(&p.id.0, target));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn h(tag: u64) -> Hash256 {
        Hash256::of(&tag.to_le_bytes())
    }

    #[test]
    fn ring_distance_symmetric_and_zero_on_self() {
        let a = h(1);
        let b = h(2);
        assert_eq!(ring_distance(&a, &b), ring_distance(&b, &a));
        assert_eq!(ring_distance(&a, &a), 0);
    }

    #[test]
    fn ring_distance_wraparound() {
        let lo = Hash256([0u8; 32]);
        let mut hi_bytes = [0xffu8; 32];
        hi_bytes[16..].fill(0);
        let hi = Hash256(hi_bytes); // prefix = u128::MAX
        assert_eq!(ring_distance(&lo, &hi), 1); // adjacent across the seam
    }

    #[test]
    fn rank_distance_scales_with_population() {
        let a = h(3);
        let b = h(4);
        let d_small = rank_distance(&a, &b, 100);
        let d_large = rank_distance(&a, &b, 10_000);
        assert!(d_large > d_small);
        assert!(rank_distance(&a, &a, 1000) >= 1.0);
    }

    #[test]
    fn rank_distance_matches_expected_rank_statistically() {
        // For random points, the j-th nearest of n nodes should have
        // rank_distance ≈ j on average.
        let mut rng = Rng::new(90);
        let n = 2000;
        let ids: Vec<Hash256> = (0..n).map(|_| {
            let mut b = [0u8; 32];
            rng.fill_bytes(&mut b);
            Hash256(b)
        }).collect();
        let target = h(99);
        let mut dists: Vec<u128> = ids.iter().map(|i| ring_distance(i, &target)).collect();
        dists.sort_unstable();
        // 10th nearest (index 9, 1-based rank 10)
        let mut fake = [0u8; 32];
        fake[..16].copy_from_slice(
            &target.prefix_u128().wrapping_add(dists[9]).to_be_bytes(),
        );
        let rd = rank_distance(&Hash256(fake), &target, n);
        assert!((2.0..40.0).contains(&rd), "rank of 10th nearest ≈ 10, got {rd}");
    }

    #[test]
    fn node_id_from_pk_deterministic() {
        let pk = [7u8; 32];
        assert_eq!(NodeId::from_pk(&pk), NodeId::from_pk(&pk));
        assert_ne!(NodeId::from_pk(&pk), NodeId::from_pk(&[8u8; 32]));
    }

    #[test]
    fn sort_by_distance_orders() {
        let mut rng = Rng::new(91);
        let mut peers: Vec<PeerInfo> = (0..50)
            .map(|_| {
                let mut pk = [0u8; 32];
                rng.fill_bytes(&mut pk);
                PeerInfo { id: NodeId::from_pk(&pk), pk, region: 0 }
            })
            .collect();
        let target = h(5);
        sort_by_ring_distance(&mut peers, &target);
        for w in peers.windows(2) {
            assert!(ring_distance(&w[0].id.0, &target) <= ring_distance(&w[1].id.0, &target));
        }
    }
}
