//! Kademlia-style k-bucket routing table.
//!
//! Buckets are indexed by the length of the common prefix between the
//! local ID and the contact (XOR metric). Least-recently-seen contacts
//! are evicted first when a bucket overflows, which biases the table
//! toward long-lived peers — the classic Kademlia churn resistance.

use super::{xor_distance, NodeId, PeerInfo};
use crate::crypto::Hash256;

pub const BUCKET_SIZE: usize = 20; // Kademlia k

#[derive(Clone, Debug)]
pub struct RoutingTable {
    local: NodeId,
    /// buckets[i] holds contacts whose XOR distance has i leading zeros.
    buckets: Vec<Vec<PeerInfo>>,
}

impl RoutingTable {
    pub fn new(local: NodeId) -> Self {
        RoutingTable { local, buckets: vec![Vec::new(); 257] }
    }

    pub fn local(&self) -> NodeId {
        self.local
    }

    fn bucket_index(&self, id: &NodeId) -> usize {
        (self.local.0.xor(&id.0).leading_zeros() as usize).min(256)
    }

    /// Record contact with a peer (moves it to most-recently-seen).
    pub fn touch(&mut self, peer: PeerInfo) {
        if peer.id == self.local {
            return;
        }
        let idx = self.bucket_index(&peer.id);
        let bucket = &mut self.buckets[idx];
        if let Some(pos) = bucket.iter().position(|p| p.id == peer.id) {
            bucket.remove(pos);
            bucket.push(peer);
            return;
        }
        if bucket.len() < BUCKET_SIZE {
            bucket.push(peer);
        } else {
            // Evict least-recently-seen (front). Production Kademlia
            // pings it first; our transports report failures directly
            // via `remove`, so immediate replacement is fine.
            bucket.remove(0);
            bucket.push(peer);
        }
    }

    pub fn remove(&mut self, id: &NodeId) {
        let idx = self.bucket_index(id);
        self.buckets[idx].retain(|p| p.id != *id);
    }

    pub fn contains(&self, id: &NodeId) -> bool {
        let idx = self.bucket_index(id);
        self.buckets[idx].iter().any(|p| p.id == *id)
    }

    pub fn len(&self) -> usize {
        self.buckets.iter().map(|b| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `count` known contacts closest (XOR metric) to `target`.
    pub fn closest(&self, target: &Hash256, count: usize) -> Vec<PeerInfo> {
        let mut all: Vec<PeerInfo> = self.buckets.iter().flatten().copied().collect();
        all.sort_by_key(|p| xor_distance(&p.id, target));
        all.truncate(count);
        all
    }

    pub fn all(&self) -> Vec<PeerInfo> {
        self.buckets.iter().flatten().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn peer(rng: &mut Rng) -> PeerInfo {
        let mut pk = [0u8; 32];
        rng.fill_bytes(&mut pk);
        PeerInfo { id: NodeId::from_pk(&pk), pk, region: 0 }
    }

    #[test]
    fn touch_and_contains() {
        let mut rng = Rng::new(100);
        let local = peer(&mut rng);
        let mut rt = RoutingTable::new(local.id);
        let p = peer(&mut rng);
        rt.touch(p);
        assert!(rt.contains(&p.id));
        assert_eq!(rt.len(), 1);
        rt.remove(&p.id);
        assert!(!rt.contains(&p.id));
    }

    #[test]
    fn ignores_self() {
        let mut rng = Rng::new(101);
        let local = peer(&mut rng);
        let mut rt = RoutingTable::new(local.id);
        rt.touch(local);
        assert_eq!(rt.len(), 0);
    }

    #[test]
    fn closest_returns_sorted_by_xor() {
        let mut rng = Rng::new(102);
        let local = peer(&mut rng);
        let mut rt = RoutingTable::new(local.id);
        for _ in 0..200 {
            rt.touch(peer(&mut rng));
        }
        let target = Hash256::of(b"target");
        let closest = rt.closest(&target, 10);
        assert_eq!(closest.len(), 10);
        for w in closest.windows(2) {
            assert!(
                xor_distance(&w[0].id, &target).0 <= xor_distance(&w[1].id, &target).0
            );
        }
        // Must actually be the globally closest among table entries.
        let mut all = rt.all();
        all.sort_by_key(|p| xor_distance(&p.id, &target));
        assert_eq!(closest[0].id, all[0].id);
    }

    #[test]
    fn bucket_overflow_evicts_lru() {
        let mut rng = Rng::new(103);
        let local = peer(&mut rng);
        let mut rt = RoutingTable::new(local.id);
        // Flood with many random peers; table must stay bounded.
        for _ in 0..5000 {
            rt.touch(peer(&mut rng));
        }
        assert!(rt.len() <= 257 * BUCKET_SIZE);
        // Most-recently-touched stays resident.
        let p = peer(&mut rng);
        rt.touch(p);
        for _ in 0..BUCKET_SIZE * 2 {
            rt.touch(peer(&mut rng));
            rt.touch(p); // keep refreshing
        }
        assert!(rt.contains(&p.id));
    }
}
