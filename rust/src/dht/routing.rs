//! Kademlia-style k-bucket routing table.
//!
//! Buckets are indexed by the length of the common prefix between the
//! local ID and the contact (XOR metric). Least-recently-seen contacts
//! are evicted first when a bucket overflows, which biases the table
//! toward long-lived peers — the classic Kademlia churn resistance.

use super::{xor_distance, NodeId, PeerInfo};
use crate::crypto::Hash256;
use crate::util::detmap::DetHashSet;

pub const BUCKET_SIZE: usize = 20; // Kademlia k

/// With the diversity guard on, at most this many contacts of one
/// latency region may occupy a single bucket. An eclipse attacker
/// spinning sybils from one hosting cluster caps out at a quarter of
/// each bucket; filling a victim's table requires presence the
/// attacker must actually buy in every region.
pub const MAX_PER_REGION: usize = BUCKET_SIZE / 4;

#[derive(Clone, Debug)]
pub struct RoutingTable {
    local: NodeId,
    /// buckets[i] holds contacts whose XOR distance has i leading zeros.
    buckets: Vec<Vec<PeerInfo>>,
    /// Bucket-diversity guard (ISSUE 8): per-region occupancy cap plus
    /// verified-contact retention. Off by default — `new` preserves
    /// the classic LRU table bit-for-bit.
    guard: bool,
    /// Contacts that completed an authenticated exchange (signed
    /// heartbeat, verified claim). A merely-gossiped contact can never
    /// evict one of these.
    verified: DetHashSet<NodeId>,
}

impl RoutingTable {
    pub fn new(local: NodeId) -> Self {
        RoutingTable {
            local,
            buckets: vec![Vec::new(); 257],
            guard: false,
            verified: DetHashSet::default(),
        }
    }

    /// A table with the eclipse-resistance guard enabled.
    pub fn with_guard(local: NodeId) -> Self {
        let mut rt = Self::new(local);
        rt.guard = true;
        rt
    }

    pub fn local(&self) -> NodeId {
        self.local
    }

    fn bucket_index(&self, id: &NodeId) -> usize {
        (self.local.0.xor(&id.0).leading_zeros() as usize).min(256)
    }

    /// Record contact with a peer (moves it to most-recently-seen).
    pub fn touch(&mut self, peer: PeerInfo) {
        self.touch_inner(peer);
    }

    /// Record an *authenticated* contact: the peer proved key
    /// possession to us, so (under the guard) it gains eviction
    /// preference over gossiped-only contacts.
    pub fn touch_verified(&mut self, peer: PeerInfo) {
        if self.guard && peer.id != self.local {
            self.verified.insert(peer.id);
        }
        self.touch_inner(peer);
    }

    fn touch_inner(&mut self, peer: PeerInfo) {
        if peer.id == self.local {
            return;
        }
        let idx = self.bucket_index(&peer.id);
        let bucket = &mut self.buckets[idx];
        if let Some(pos) = bucket.iter().position(|p| p.id == peer.id) {
            bucket.remove(pos);
            bucket.push(peer);
            return;
        }
        if self.guard {
            // Region cap: refuse the insert outright when this
            // bucket already holds its quota from the peer's region.
            let same_region = bucket.iter().filter(|p| p.region == peer.region).count();
            if same_region >= MAX_PER_REGION {
                return;
            }
        }
        if bucket.len() < BUCKET_SIZE {
            bucket.push(peer);
        } else if self.guard {
            // Evict the least-recently-seen *unverified* contact;
            // if every resident proved its key, the newcomer waits
            // (classic Kademlia long-lived bias, hardened).
            if let Some(pos) = bucket.iter().position(|p| !self.verified.contains(&p.id)) {
                bucket.remove(pos);
                bucket.push(peer);
            }
        } else {
            // Evict least-recently-seen (front). Production Kademlia
            // pings it first; our transports report failures directly
            // via `remove`, so immediate replacement is fine.
            bucket.remove(0);
            bucket.push(peer);
        }
    }

    pub fn remove(&mut self, id: &NodeId) {
        let idx = self.bucket_index(id);
        self.buckets[idx].retain(|p| p.id != *id);
        self.verified.remove(id);
    }

    pub fn contains(&self, id: &NodeId) -> bool {
        let idx = self.bucket_index(id);
        self.buckets[idx].iter().any(|p| p.id == *id)
    }

    pub fn len(&self) -> usize {
        self.buckets.iter().map(|b| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `count` known contacts closest (XOR metric) to `target`.
    pub fn closest(&self, target: &Hash256, count: usize) -> Vec<PeerInfo> {
        let mut all: Vec<PeerInfo> = self.buckets.iter().flatten().copied().collect();
        all.sort_by_key(|p| xor_distance(&p.id, target));
        all.truncate(count);
        all
    }

    pub fn all(&self) -> Vec<PeerInfo> {
        self.buckets.iter().flatten().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn peer(rng: &mut Rng) -> PeerInfo {
        let mut pk = [0u8; 32];
        rng.fill_bytes(&mut pk);
        PeerInfo { id: NodeId::from_pk(&pk), pk, region: 0 }
    }

    #[test]
    fn touch_and_contains() {
        let mut rng = Rng::new(100);
        let local = peer(&mut rng);
        let mut rt = RoutingTable::new(local.id);
        let p = peer(&mut rng);
        rt.touch(p);
        assert!(rt.contains(&p.id));
        assert_eq!(rt.len(), 1);
        rt.remove(&p.id);
        assert!(!rt.contains(&p.id));
    }

    #[test]
    fn ignores_self() {
        let mut rng = Rng::new(101);
        let local = peer(&mut rng);
        let mut rt = RoutingTable::new(local.id);
        rt.touch(local);
        assert_eq!(rt.len(), 0);
    }

    #[test]
    fn closest_returns_sorted_by_xor() {
        let mut rng = Rng::new(102);
        let local = peer(&mut rng);
        let mut rt = RoutingTable::new(local.id);
        for _ in 0..200 {
            rt.touch(peer(&mut rng));
        }
        let target = Hash256::of(b"target");
        let closest = rt.closest(&target, 10);
        assert_eq!(closest.len(), 10);
        for w in closest.windows(2) {
            assert!(
                xor_distance(&w[0].id, &target).0 <= xor_distance(&w[1].id, &target).0
            );
        }
        // Must actually be the globally closest among table entries.
        let mut all = rt.all();
        all.sort_by_key(|p| xor_distance(&p.id, &target));
        assert_eq!(closest[0].id, all[0].id);
    }

    #[test]
    fn bucket_overflow_evicts_lru() {
        let mut rng = Rng::new(103);
        let local = peer(&mut rng);
        let mut rt = RoutingTable::new(local.id);
        // Flood with many random peers; table must stay bounded.
        for _ in 0..5000 {
            rt.touch(peer(&mut rng));
        }
        assert!(rt.len() <= 257 * BUCKET_SIZE);
        // Most-recently-touched stays resident.
        let p = peer(&mut rng);
        rt.touch(p);
        for _ in 0..BUCKET_SIZE * 2 {
            rt.touch(peer(&mut rng));
            rt.touch(p); // keep refreshing
        }
        assert!(rt.contains(&p.id));
    }

    fn peer_in_region(rng: &mut Rng, region: u8) -> PeerInfo {
        let mut p = peer(rng);
        p.region = region;
        p
    }

    #[test]
    fn guard_caps_contacts_per_region_per_bucket() {
        let mut rng = Rng::new(104);
        let local = peer(&mut rng);
        let mut rt = RoutingTable::with_guard(local.id);
        // A single-region sybil flood: every bucket must cap out at
        // MAX_PER_REGION residents from that region.
        for _ in 0..5000 {
            rt.touch(peer_in_region(&mut rng, 3));
        }
        for idx in 0..257 {
            let in_bucket: Vec<PeerInfo> =
                rt.all().into_iter().filter(|p| rt.bucket_index(&p.id) == idx).collect();
            let same: usize = in_bucket.iter().filter(|p| p.region == 3).count();
            assert!(same <= MAX_PER_REGION, "bucket {idx} holds {same} region-3 contacts");
        }
        // An unguarded table takes the whole flood.
        let mut legacy = RoutingTable::new(local.id);
        let mut rng2 = Rng::new(104);
        let _ = peer(&mut rng2); // consume the local draw
        for _ in 0..5000 {
            legacy.touch(peer_in_region(&mut rng2, 3));
        }
        assert!(legacy.len() > rt.len(), "guard must shrink a monoculture flood");
    }

    #[test]
    fn guard_never_evicts_verified_for_gossiped() {
        let mut rng = Rng::new(105);
        let local = peer(&mut rng);
        let mut rt = RoutingTable::with_guard(local.id);
        // Seed verified honest contacts across all regions.
        let honest: Vec<PeerInfo> =
            (0..100).map(|i| peer_in_region(&mut rng, (i % 5) as u8)).collect();
        for h in &honest {
            rt.touch_verified(*h);
        }
        let resident_before: Vec<NodeId> =
            honest.iter().map(|h| h.id).filter(|id| rt.contains(id)).collect();
        assert!(!resident_before.is_empty());
        // Gossiped sybil flood, spread over every region so the region
        // cap alone doesn't stop it.
        for i in 0..5000u32 {
            rt.touch(peer_in_region(&mut rng, (i % 5) as u8));
        }
        for id in &resident_before {
            assert!(rt.contains(id), "verified contact evicted by gossiped flood");
        }
        // The legacy table loses most verified residents to the same flood.
        let mut legacy = RoutingTable::new(local.id);
        for h in &honest {
            legacy.touch(*h);
        }
        let mut rng3 = Rng::new(106);
        for i in 0..5000u32 {
            legacy.touch(peer_in_region(&mut rng3, (i % 5) as u8));
        }
        let survivors =
            resident_before.iter().filter(|id| legacy.contains(id)).count();
        assert!(
            survivors < resident_before.len(),
            "flood should displace unguarded contacts ({survivors} survived)"
        );
    }

    #[test]
    fn guard_still_refreshes_and_removes() {
        let mut rng = Rng::new(107);
        let local = peer(&mut rng);
        let mut rt = RoutingTable::with_guard(local.id);
        let p = peer(&mut rng);
        rt.touch_verified(p);
        rt.touch(p); // refresh of a resident is always allowed
        assert!(rt.contains(&p.id));
        rt.remove(&p.id);
        assert!(!rt.contains(&p.id));
        // After removal the verified mark is gone too: a full bucket
        // of new arrivals can evict it if it ever returns unverified.
        rt.touch(p);
        assert!(rt.contains(&p.id));
    }
}
