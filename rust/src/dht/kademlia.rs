//! Iterative Kademlia lookup as a transport-agnostic state machine.
//!
//! Used by the TCP deployment mode, where no oracle exists. The simnet
//! evaluation path uses constant-time oracle discovery instead — the
//! same simplification the paper makes in §6.2 ("a simulated DHT
//! routing system that provides node discovery in constant time ...
//! mitigates the effect of DHT routing performance on the result").

use super::{xor_distance, NodeId, PeerInfo};
use crate::crypto::Hash256;
use std::collections::HashSet;

pub const ALPHA: usize = 3; // lookup parallelism

/// Outcome of a deterministic eclipse trial ([`eclipse_trial`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct EclipseReport {
    /// Lookups attempted by the victim.
    pub lookups: u64,
    /// Lookups whose converged result set contained at least one
    /// honest peer (the availability proxy: an honest holder is
    /// reachable through routing).
    pub honest_reach: u64,
    /// Sybil / honest contacts resident in the victim's table after
    /// the poisoning flood.
    pub sybils_resident: u64,
    pub honest_resident: u64,
}

impl EclipseReport {
    pub fn reach_frac(&self) -> f64 {
        if self.lookups == 0 {
            return 0.0;
        }
        self.honest_reach as f64 / self.lookups as f64
    }
}

/// Deterministic routing-table-poisoning model (ISSUE 8), shared by
/// the `Fault::Eclipse` scenario arm, `examples/eclipse_defense.rs`,
/// and `vault bench-adversary`.
///
/// A victim first learns `n_honest` peers through authenticated
/// exchanges (`touch_verified`), then an attacker gossips `n_sybil`
/// sybil contacts — all minted in one region (a single hosting
/// cluster) — `flood_rounds` times over. Sybil FIND_NODE replies
/// return only fellow sybils; honest replies return honest routing
/// knowledge. The report measures how often the victim's lookups can
/// still reach *any* honest peer. With `guard` off the LRU table is
/// progressively captured; with the bucket-diversity guard on, the
/// region cap plus verified-contact retention keeps honest routes
/// resident — eclipse would now require verified presence in every
/// region, diversity the attacker must actually buy.
pub fn eclipse_trial(
    n_honest: usize,
    n_sybil: usize,
    flood_rounds: usize,
    lookups: usize,
    seed: u64,
    guard: bool,
) -> EclipseReport {
    use crate::dht::routing::RoutingTable;
    use crate::util::rng::Rng;

    let mut rng = Rng::new(seed ^ 0xEC11_95E0);
    let mk_peer = |rng: &mut Rng, region: u8| {
        let mut pk = [0u8; 32];
        rng.fill_bytes(&mut pk);
        PeerInfo { id: NodeId::from_pk(&pk), pk, region }
    };
    let victim = mk_peer(&mut rng, 0);
    let honest: Vec<PeerInfo> =
        (0..n_honest).map(|i| mk_peer(&mut rng, (i % 5) as u8)).collect();
    // Monoculture sybils: one region, zero diversity cost.
    let sybils: Vec<PeerInfo> = (0..n_sybil).map(|_| mk_peer(&mut rng, 0)).collect();
    let honest_ids: HashSet<NodeId> = honest.iter().map(|p| p.id).collect();

    let mut table =
        if guard { RoutingTable::with_guard(victim.id) } else { RoutingTable::new(victim.id) };
    for h in &honest {
        table.touch_verified(*h);
    }
    // The poisoning flood: gossiped (unauthenticated) sybil contacts,
    // repeated so LRU tables are fully churned through.
    for _ in 0..flood_rounds {
        for s in &sybils {
            table.touch(*s);
        }
    }

    let mut report = EclipseReport::default();
    for p in table.all() {
        if honest_ids.contains(&p.id) {
            report.honest_resident += 1;
        } else {
            report.sybils_resident += 1;
        }
    }

    for _ in 0..lookups {
        let mut target = [0u8; 32];
        rng.fill_bytes(&mut target);
        let target = Hash256(target);
        let seeds = table.closest(&target, ALPHA);
        if seeds.is_empty() {
            report.lookups += 1;
            continue;
        }
        let mut lookup = Lookup::new(target, seeds, 8);
        let found = loop {
            match lookup.next_action() {
                LookupAction::Query(qs) => {
                    for q in qs {
                        if honest_ids.contains(&q.id) {
                            // Honest node: answers from honest routing
                            // knowledge (its own table is unpoisoned).
                            let mut closer = honest.clone();
                            closer.sort_by_key(|p| xor_distance(&p.id, &target));
                            closer.truncate(20);
                            lookup.on_reply(q.id, closer);
                        } else {
                            // Sybil: answers only with fellow sybils.
                            let mut closer = sybils.clone();
                            closer.sort_by_key(|p| xor_distance(&p.id, &target));
                            closer.truncate(20);
                            lookup.on_reply(q.id, closer);
                        }
                    }
                }
                LookupAction::Wait => unreachable!("synchronous driver"),
                LookupAction::Done(found) => break found,
            }
        };
        report.lookups += 1;
        if found.iter().any(|p| honest_ids.contains(&p.id)) {
            report.honest_reach += 1;
        }
    }
    report
}

/// One in-flight iterative FIND_NODE lookup.
#[derive(Debug)]
pub struct Lookup {
    pub target: Hash256,
    want: usize,
    /// Known candidates, sorted by XOR distance, with query state.
    shortlist: Vec<(PeerInfo, QueryState)>,
    queried: HashSet<NodeId>,
    in_flight: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum QueryState {
    Fresh,
    InFlight,
    Responded,
    Failed,
}

/// What the driver should do next.
#[derive(Debug, PartialEq, Eq)]
pub enum LookupAction {
    /// Send FIND_NODE(target) to these peers.
    Query(Vec<PeerInfo>),
    /// Lookup converged; the closest `want` responsive peers.
    Done(Vec<PeerInfo>),
    /// Waiting for in-flight replies.
    Wait,
}

impl Lookup {
    pub fn new(target: Hash256, seeds: Vec<PeerInfo>, want: usize) -> Self {
        let mut l = Lookup {
            target,
            want,
            shortlist: Vec::new(),
            queried: HashSet::new(),
            in_flight: 0,
        };
        for s in seeds {
            l.insert(s);
        }
        l
    }

    fn insert(&mut self, peer: PeerInfo) {
        if self.shortlist.iter().any(|(p, _)| p.id == peer.id) {
            return;
        }
        self.shortlist.push((peer, QueryState::Fresh));
        let t = self.target;
        self.shortlist.sort_by_key(|(p, _)| xor_distance(&p.id, &t));
    }

    /// Ask the state machine what to do.
    pub fn next_action(&mut self) -> LookupAction {
        // Converged when the closest `want` responsive candidates have
        // all responded and nothing fresh is closer.
        let mut to_query = Vec::new();
        for (p, st) in self.shortlist.iter_mut() {
            if to_query.len() + self.in_flight >= ALPHA {
                break;
            }
            if *st == QueryState::Fresh {
                *st = QueryState::InFlight;
                to_query.push(*p);
            }
        }
        if !to_query.is_empty() {
            self.in_flight += to_query.len();
            for p in &to_query {
                self.queried.insert(p.id);
            }
            return LookupAction::Query(to_query);
        }
        if self.in_flight > 0 {
            return LookupAction::Wait;
        }
        // No fresh, none in flight: done.
        let done: Vec<PeerInfo> = self
            .shortlist
            .iter()
            .filter(|(_, st)| *st == QueryState::Responded)
            .map(|(p, _)| *p)
            .take(self.want)
            .collect();
        LookupAction::Done(done)
    }

    /// Record a FIND_NODE reply carrying closer peers.
    pub fn on_reply(&mut self, from: NodeId, closer: Vec<PeerInfo>) {
        let mut was_in_flight = false;
        for (p, st) in self.shortlist.iter_mut() {
            if p.id == from && *st == QueryState::InFlight {
                *st = QueryState::Responded;
                was_in_flight = true;
                break;
            }
        }
        if was_in_flight {
            self.in_flight -= 1;
        }
        for c in closer {
            if !self.queried.contains(&c.id) {
                self.insert(c);
            }
        }
    }

    /// Record a query failure (timeout / refused).
    pub fn on_failure(&mut self, from: NodeId) {
        for (p, st) in self.shortlist.iter_mut() {
            if p.id == from && *st == QueryState::InFlight {
                *st = QueryState::Failed;
                self.in_flight -= 1;
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dht::routing::RoutingTable;
    use crate::util::rng::Rng;

    /// Simulate a static network of `n` peers with full routing tables
    /// and drive a lookup to completion synchronously.
    fn run_lookup(n: usize, seed: u64) -> (Vec<PeerInfo>, Vec<PeerInfo>) {
        let mut rng = Rng::new(seed);
        let peers: Vec<PeerInfo> = (0..n)
            .map(|_| {
                let mut pk = [0u8; 32];
                rng.fill_bytes(&mut pk);
                PeerInfo { id: NodeId::from_pk(&pk), pk, region: 0 }
            })
            .collect();
        let mut tables: std::collections::HashMap<NodeId, RoutingTable> =
            std::collections::HashMap::new();
        for p in &peers {
            let mut rt = RoutingTable::new(p.id);
            for q in &peers {
                rt.touch(*q);
            }
            tables.insert(p.id, rt);
        }
        let target = Hash256::of(&seed.to_le_bytes());
        let seeds = vec![peers[0], peers[1], peers[2]];
        let mut lookup = Lookup::new(target, seeds, 8);
        loop {
            match lookup.next_action() {
                LookupAction::Query(qs) => {
                    for q in qs {
                        let closer = tables[&q.id].closest(&target, 20);
                        lookup.on_reply(q.id, closer);
                    }
                }
                LookupAction::Wait => unreachable!("synchronous driver"),
                LookupAction::Done(found) => {
                    let mut truth = peers.clone();
                    truth.sort_by_key(|p| xor_distance(&p.id, &target));
                    truth.truncate(8);
                    return (found, truth);
                }
            }
        }
    }

    #[test]
    fn lookup_finds_globally_closest() {
        for seed in [1u64, 2, 3] {
            let (found, truth) = run_lookup(300, seed);
            assert_eq!(found.len(), 8);
            let found_ids: std::collections::HashSet<_> = found.iter().map(|p| p.id).collect();
            // All of the true top-8 should be discovered (full tables).
            for t in &truth {
                assert!(found_ids.contains(&t.id), "missing {:?}", t.id);
            }
        }
    }

    #[test]
    fn lookup_survives_failures() {
        let mut rng = Rng::new(7);
        let peers: Vec<PeerInfo> = (0..100)
            .map(|_| {
                let mut pk = [0u8; 32];
                rng.fill_bytes(&mut pk);
                PeerInfo { id: NodeId::from_pk(&pk), pk, region: 0 }
            })
            .collect();
        let target = Hash256::of(b"t");
        let mut lookup = Lookup::new(target, peers[..10].to_vec(), 5);
        let mut done = None;
        let mut step = 0;
        while done.is_none() {
            step += 1;
            assert!(step < 1000);
            match lookup.next_action() {
                LookupAction::Query(qs) => {
                    for (i, q) in qs.into_iter().enumerate() {
                        if i % 2 == 0 {
                            lookup.on_failure(q.id); // half the queries fail
                        } else {
                            lookup.on_reply(q.id, peers[10..40].to_vec());
                        }
                    }
                }
                LookupAction::Wait => unreachable!(),
                LookupAction::Done(found) => done = Some(found),
            }
        }
        assert!(!done.unwrap().is_empty());
    }

    #[test]
    fn eclipse_trial_guard_preserves_honest_reach() {
        for seed in [1u64, 2] {
            let off = eclipse_trial(100, 300, 3, 40, seed, false);
            let on = eclipse_trial(100, 300, 3, 40, seed, true);
            assert_eq!(off.lookups, 40);
            assert_eq!(on.lookups, 40);
            assert!(
                on.honest_resident > off.honest_resident,
                "guard must keep more honest contacts resident (on={} off={})",
                on.honest_resident,
                off.honest_resident
            );
            assert!(
                on.reach_frac() > off.reach_frac(),
                "guard must measurably improve honest reach (on={} off={})",
                on.reach_frac(),
                off.reach_frac()
            );
            assert!(on.reach_frac() >= 0.9, "guarded reach floor: {}", on.reach_frac());
        }
    }

    #[test]
    fn eclipse_trial_is_deterministic() {
        let a = eclipse_trial(60, 120, 2, 10, 9, true);
        let b = eclipse_trial(60, 120, 2, 10, 9, true);
        assert_eq!(a.honest_reach, b.honest_reach);
        assert_eq!(a.sybils_resident, b.sybils_resident);
        assert_eq!(a.honest_resident, b.honest_resident);
    }
}
