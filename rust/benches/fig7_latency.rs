//! Fig. 7: STORE / QUERY / repair latency in a world-wide (5-region)
//! deployment, varying the outer code (top) and inner code (bottom),
//! against the IPFS-like Kademlia baseline.
//!
//! Latencies are virtual-time over the measured inter-region RTT matrix
//! (DESIGN.md §Substitutions). Run:
//! `cargo bench --bench fig7_latency [-- --peers 400 --ops 3]`

use vault::baseline::ipfs_like::{IpfsConfig, IpfsNet};
use vault::coordinator::{Cluster, ClusterConfig};
use vault::proto::{AppEvent, ClaimVerify, VaultConfig};
use vault::util::cli::Args;
use vault::util::rng::Rng;
use vault::util::stats::Samples;

struct Measured {
    store: Samples,
    query: Samples,
    repair: Samples,
}

fn measure(peers: usize, ops: usize, size: usize, vault_cfg: VaultConfig, seed: u64) -> Measured {
    let cfg = ClusterConfig {
        peers,
        seed,
        vault: vault_cfg,
        byzantine_frac: 0.0,
        ..Default::default()
    };
    let mut cluster = Cluster::start(cfg);
    let mut rng = Rng::new(seed);
    let mut m = Measured { store: Samples::new(), query: Samples::new(), repair: Samples::new() };
    for _ in 0..ops {
        let mut data = vec![0u8; size];
        rng.fill_bytes(&mut data);
        let c1 = cluster.random_client();
        let Ok(stored) = cluster.store_blocking(c1, &data, b"fig7", 0) else { continue };
        m.store.push(stored.latency_ms as f64);
        let c2 = cluster.random_client();
        if let Ok(q) = cluster.query_blocking(c2, &stored.value) {
            assert_eq!(q.value, data);
            m.query.push(q.latency_ms as f64);
        }
        // Repair latency: evict one member, time until a RepairJoined
        // event for that chunk arrives.
        let chash = stored.value.chunks[0];
        cluster.evict_one_member(&chash);
        let start = cluster.net.now_ms();
        let deadline = start + 240_000;
        'repair: while cluster.net.now_ms() < deadline {
            for (_, ev) in cluster.net.run_for(2_000) {
                if let AppEvent::RepairJoined { chash: c, .. } = ev {
                    if c == chash {
                        m.repair.push((cluster.net.now_ms() - start) as f64);
                        break 'repair;
                    }
                }
            }
        }
    }
    m
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let peers = args.get("peers", 300usize);
    let ops = args.get("ops", 2usize);
    let size = args.get("size", 1 << 22); // 4 MiB (1 GiB in the paper)

    let base_cfg = |k_inner: usize, r_inner: usize, k_outer: usize, n_outer: usize| VaultConfig {
        k_inner,
        r_inner,
        k_outer,
        n_outer,
        n_nodes: peers,
        candidates: (3 * r_inner).min(peers),
        fetch_fanout: k_inner + 8,
        heartbeat_ms: 20_000,
        suspicion_ms: 60_000,
        tick_ms: 10_000,
        claim_verify: ClaimVerify::Never, // harness knob; see DESIGN.md
        ..Default::default()
    };

    println!("# Fig 7 (top): latency vs outer code (inner fixed (32,80)); ms virtual");
    println!("{:>12} {:>10} {:>10} {:>10}", "outer", "store", "query", "repair");
    for (n_outer, k_outer) in [(10usize, 8usize), (12, 8), (14, 8)] {
        let m = measure(peers, ops, size, base_cfg(32, 80, k_outer, n_outer), 21);
        println!(
            "{:>12} {:>10.0} {:>10.0} {:>10.0}",
            format!("({n_outer},{k_outer})"),
            m.store.mean(),
            m.query.mean(),
            m.repair.mean()
        );
    }

    println!("\n# Fig 7 (bottom): latency vs inner code (outer fixed (10,8)); ms virtual");
    println!("{:>12} {:>10} {:>10} {:>10}", "inner", "store", "query", "repair");
    for (k_inner, r_inner) in [(16usize, 40usize), (32, 80), (48, 120)] {
        let m = measure(peers, ops, size, base_cfg(k_inner, r_inner, 8, 10), 22);
        println!(
            "{:>12} {:>10.0} {:>10.0} {:>10.0}",
            format!("({k_inner},{r_inner})"),
            m.store.mean(),
            m.query.mean(),
            m.repair.mean()
        );
    }

    println!("\n# IPFS-like baseline (replication 3, 256 records/object)");
    let mut net = IpfsNet::new(IpfsConfig { n_peers: peers, seed: 23, ..Default::default() });
    let mut store = Samples::new();
    let mut query = Samples::new();
    let mut repair = Samples::new();
    for i in 0..ops as u64 {
        let (handle, op) = net.store((i % 5) as u8, size, i);
        if let Some(lat) = net.run_until_op(op) {
            store.push(lat as f64);
        }
        let qop = net.query(((i + 2) % 5) as u8, &handle);
        if let Some(lat) = net.run_until_op(qop) {
            query.push(lat as f64);
        }
        let rop = net.repair_record(&handle.keys[0], handle.record_size);
        if let Some(lat) = net.run_until_op(rop) {
            repair.push(lat as f64);
        }
    }
    println!(
        "{:>12} {:>10.0} {:>10.0} {:>10.0}",
        "baseline",
        store.mean(),
        query.mean(),
        repair.mean()
    );
}
