//! Appendix A reproductions: Lemma 4.1 CTMC absorbing series (native +
//! XLA artifact), Eq. (3)/(4) initial-state validity, and the Lemma 4.2
//! targeted-attack bound.
//!
//! Run: `cargo bench --bench lemma_bounds`

use vault::analysis::{bounds, ctmc};
use vault::runtime::{default_artifact_dir, Runtime};
use vault::util::Timer;

fn main() {
    println!("# Lemma 4.1: group-loss probability series (I*Theta^T)_absorb");
    println!("{:>14} {:>12} {:>12} {:>12} {:>12}", "config", "T=24", "T=168", "T=512", "object(K+R)");
    for (n, k, q) in [(80usize, 32usize, 0.002f64), (80, 32, 0.01), (48, 32, 0.002), (160, 64, 0.01)] {
        let chain = ctmc::build_chain(&ctmc::CtmcConfig { n, k, churn_q: q, ..Default::default() });
        let s = chain.absorb_series(512);
        println!(
            "{:>14} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e}",
            format!("({n},{k})q={q}"),
            s[23],
            s[167],
            s[511],
            chain.object_loss_bound(512, 10)
        );
    }

    // Native vs artifact timing + agreement.
    if Runtime::artifacts_available(&default_artifact_dir()) {
        let rt = Runtime::load(&default_artifact_dir()).expect("artifacts");
        let chain = ctmc::build_chain(&ctmc::CtmcConfig {
            n: 60,
            k: 32,
            churn_q: 0.01,
            ..Default::default()
        });
        let t = Timer::start();
        let native = chain.absorb_series(512);
        let native_ms = t.elapsed_ms();
        let (theta, init, absorb) = chain.padded(64);
        let t = Timer::start();
        let art = rt.ctmc_series(&theta, &init, absorb, 512).expect("artifact");
        let art_ms = t.elapsed_ms();
        let max_err = native
            .iter()
            .zip(&art)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        println!("# ctmc artifact vs native: max |err| = {max_err:.2e} (native {native_ms:.1} ms, artifact {art_ms:.1} ms)");
    } else {
        println!("# (ctmc artifact not built — run `make artifacts`)");
    }

    println!("\n# Eq. (3)/(4): initial-state invalid probability, F = N/3");
    println!("{:>10} {:>6} {:>14} {:>14}", "n", "k", "exact", "hoeffding");
    for (n, k) in [(80u64, 32u64), (80, 40), (48, 32), (160, 64), (40, 32)] {
        println!(
            "{n:>10} {k:>6} {:>14.3e} {:>14.3e}",
            bounds::initial_invalid_prob(100_000, 33_333, n, k),
            bounds::initial_invalid_hoeffding(n, k)
        );
    }

    println!("\n# Lemma 4.2: targeted-attack success bound (Omega objects, K=8, R=2)");
    println!("{:>10} {:>10} {:>8} {:>14}", "objects", "phi", "mu", "bound");
    for omega in [1_000u64, 10_000, 100_000] {
        for phi in [100u64, 1_000, 10_000] {
            for mu in [1u64, 8] {
                println!(
                    "{omega:>10} {phi:>10} {mu:>8} {:>14.3e}",
                    bounds::targeted_attack_bound(omega, 8, 2, phi, mu)
                );
            }
        }
    }
}
