//! §Perf instrument: micro-benchmarks of every hot path the protocol
//! touches. Feeds EXPERIMENTS.md §Perf before/after entries.
//!
//! Run: `cargo bench --bench perf_hotpath`

use vault::codec::rateless::{coeff_row, InnerDecoder, InnerEncoder};
use vault::codec::xor::xor_into;
use vault::codec::{gf256, outer};
use vault::crypto::ed25519::SigningKey;
use vault::crypto::{vrf, Hash256};
use vault::proto::selection;
use vault::util::rng::Rng;
use vault::util::Timer;

fn bench<F: FnMut()>(name: &str, iters: usize, bytes_per_iter: usize, mut f: F) {
    // Warmup.
    for _ in 0..iters.div_ceil(10).min(3) {
        f();
    }
    let t = Timer::start();
    for _ in 0..iters {
        f();
    }
    let total_s = t.elapsed_s();
    let per_iter = total_s / iters as f64;
    if bytes_per_iter > 0 {
        let mbps = bytes_per_iter as f64 * iters as f64 / total_s / 1e6;
        println!("{name:<38} {:>10.3} ms/iter {:>10.0} MB/s", per_iter * 1e3, mbps);
    } else {
        println!("{name:<38} {:>10.3} ms/iter", per_iter * 1e3);
    }
}

fn main() {
    let mut rng = Rng::new(0xBE);

    // L3 byte-level hot loops.
    let mut a = vec![0u8; 1 << 20];
    let mut b = vec![0u8; 1 << 20];
    rng.fill_bytes(&mut a);
    rng.fill_bytes(&mut b);
    bench("xor_into 1MiB", 200, 1 << 20, || xor_into(&mut a, &b));
    bench("gf256::addmul 1MiB", 50, 1 << 20, || gf256::addmul_slice(&mut a, &b, 0xA7));

    // Fountain code.
    let chunk = {
        let mut c = vec![0u8; 512 << 10]; // one paper chunk (4MiB/8)
        rng.fill_bytes(&mut c);
        c
    };
    let chash = Hash256::of(&chunk);
    let enc = InnerEncoder::new(chash, &chunk, 32);
    bench("inner fragment encode (512KiB/32)", 100, chunk.len() / 32, || {
        let _ = enc.fragment(12345);
    });
    bench("inner full encode R=80", 5, chunk.len() * 80 / 32, || {
        let _ = enc.fragments(&(0..80u64).collect::<Vec<_>>());
    });
    let frags: Vec<_> = (0..40u64).map(|i| enc.fragment(i)).collect();
    bench("inner decode (k=32)", 5, chunk.len(), || {
        let mut dec = InnerDecoder::new(chash, 32);
        for f in &frags {
            if dec.is_complete() {
                break;
            }
            dec.push(f);
        }
        assert!(dec.is_complete());
    });
    bench("coeff_row derivation (k=32)", 2000, 0, || {
        let _ = coeff_row(&chash, rng.next_u64(), 32);
    });

    // Outer code.
    let object = {
        let mut o = vec![0u8; 4 << 20];
        rng.fill_bytes(&mut o);
        o
    };
    bench("outer encode 4MiB (10,8)", 5, object.len(), || {
        let _ = outer::encode_object(&object, b"s", 8, 10);
    });

    // Crypto. "before" = generic double-and-add base multiplication;
    // "after" = the Point::mul_base fixed-base table (see the §Perf log).
    use vault::crypto::bigint::U256;
    use vault::crypto::point::Point;
    let k_scalar = U256::from_le_bytes(&{
        let mut b = [0u8; 32];
        rng.fill_bytes(&mut b);
        b[31] &= 0x0f;
        b
    });
    bench("base mult, double-and-add (before)", 50, 0, || {
        let _ = Point::base().mul_scalar(&k_scalar);
    });
    bench("base mult, fixed-base table (after)", 50, 0, || {
        let _ = Point::mul_base(&k_scalar);
    });
    let sk = SigningKey::from_seed(&[7; 32]);
    bench("ed25519 sign", 50, 0, || {
        let _ = sk.sign(b"persistence claim");
    });
    let sig = sk.sign(b"persistence claim");
    bench("ed25519 verify", 50, 0, || {
        assert!(vault::crypto::ed25519::verify(&sk.public, b"persistence claim", &sig));
    });
    bench("vrf prove", 20, 0, || {
        let _ = vrf::prove(&sk, b"chunk-selection-alpha");
    });
    let (_, proof) = vrf::prove(&sk, b"chunk-selection-alpha");
    bench("vrf verify", 20, 0, || {
        assert!(vrf::verify(&sk.public, b"chunk-selection-alpha", &proof).is_some());
    });
    bench("selection prove (eligible path)", 20, 0, || {
        let _ = selection::prove_selection(&sk, &chash, 1, 80, 100);
    });

    // End-to-end simnet event throughput.
    use vault::coordinator::{Cluster, ClusterConfig};
    let t = Timer::start();
    let mut cluster = Cluster::start(ClusterConfig::small_test(64));
    let data = vec![9u8; 64 << 10];
    let id = cluster.store_blocking(0, &data, b"p", 0).unwrap().value;
    let _ = cluster.query_blocking(1, &id).unwrap();
    let msgs = cluster.net.stats.msgs;
    println!(
        "{:<38} {:>10.3} s wall ({} msgs, {:.0} msg/s)",
        "simnet store+query (64 peers)",
        t.elapsed_s(),
        msgs,
        msgs as f64 / t.elapsed_s()
    );
}
