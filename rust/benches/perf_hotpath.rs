//! §Perf instrument: micro-benchmarks of every hot path the protocol
//! touches, with before/after rows for every kernel the ISSUE-3 coding
//! data-plane overhaul changed ("(ref …)" rows run the kept pre-change
//! implementations from `codec::reference`, measured in the same run on
//! the same machine). Feeds EXPERIMENTS.md §Perf entries and the
//! BENCH_codec.json trajectory.
//!
//! Run: `cargo bench --bench perf_hotpath` (append `-- --smoke` for the
//! CI rot-check at tiny iteration counts).

use vault::codec::rateless::{coeff_row, InnerDecoder, InnerEncoder};
use vault::codec::reference::{
    addmul_slice_ref, coeff_row_bools, scale_slice_ref, InnerDecoderRef, OuterDecoderRef,
};
use vault::codec::xor::xor_into;
use vault::codec::{gf256, outer, OuterDecoder};
use vault::crypto::ed25519::SigningKey;
use vault::crypto::{vrf, Hash256};
use vault::proto::selection;
use vault::util::cli::Args;
use vault::util::rng::Rng;
use vault::util::Timer;

fn bench<F: FnMut()>(name: &str, iters: usize, bytes_per_iter: usize, mut f: F) {
    // Warmup.
    for _ in 0..iters.div_ceil(10).min(3) {
        f();
    }
    let t = Timer::start();
    for _ in 0..iters {
        f();
    }
    let total_s = t.elapsed_s();
    let per_iter = total_s / iters as f64;
    if bytes_per_iter > 0 {
        let mbps = bytes_per_iter as f64 * iters as f64 / total_s / 1e6;
        println!("{name:<38} {:>10.3} ms/iter {:>10.0} MB/s", per_iter * 1e3, mbps);
    } else {
        println!("{name:<38} {:>10.3} ms/iter", per_iter * 1e3);
    }
}

fn main() {
    let args = Args::from_env();
    // --smoke: 1-2 iterations of everything so CI can prove the bench
    // targets still build and run without paying the full measurement.
    let smoke = args.bool("smoke");
    let scale = |iters: usize| if smoke { 1 } else { iters };
    let mut rng = Rng::new(0xBE);

    // L3 byte-level hot loops — before/after pairs.
    let mut a = vec![0u8; 1 << 20];
    let mut b = vec![0u8; 1 << 20];
    rng.fill_bytes(&mut a);
    rng.fill_bytes(&mut b);
    bench("xor_into 1MiB", scale(200), 1 << 20, || xor_into(&mut a, &b));
    bench("gf256::addmul 1MiB (ref per-byte)", scale(20), 1 << 20, || {
        addmul_slice_ref(&mut a, &b, 0xA7)
    });
    bench("gf256::addmul 1MiB", scale(50), 1 << 20, || gf256::addmul_slice(&mut a, &b, 0xA7));
    bench("gf256::scale 1MiB (ref per-byte)", scale(20), 1 << 20, || {
        scale_slice_ref(&mut a, 0xA7)
    });
    bench("gf256::scale 1MiB", scale(50), 1 << 20, || gf256::scale_slice(&mut a, 0xA7));

    // Fountain code.
    let chunk = {
        let mut c = vec![0u8; 512 << 10]; // one paper chunk (4MiB/8)
        rng.fill_bytes(&mut c);
        c
    };
    let chash = Hash256::of(&chunk);
    let enc = InnerEncoder::new(chash, &chunk, 32);
    bench("inner fragment encode (512KiB/32)", scale(100), chunk.len() / 32, || {
        let _ = enc.fragment(12345);
    });
    let batch: Vec<u64> = (0..80u64).collect();
    bench("inner full encode R=80", scale(5), chunk.len() * 80 / 32, || {
        let _ = enc.fragments(&batch);
    });
    let mut arena = Vec::new();
    enc.fragments_into(&batch, &mut arena); // warm the arena
    bench("inner full encode R=80 (arena reuse)", scale(5), chunk.len() * 80 / 32, || {
        enc.fragments_into(&batch, &mut arena);
    });
    let frags: Vec<_> = (0..40u64).map(|i| enc.fragment(i)).collect();
    bench("inner decode (k=32) (ref bool rows)", scale(3), chunk.len(), || {
        let mut dec = InnerDecoderRef::new(chash, 32);
        for f in &frags {
            if dec.is_complete() {
                break;
            }
            dec.push(f);
        }
        assert!(dec.is_complete());
    });
    bench("inner decode (k=32)", scale(5), chunk.len(), || {
        let mut dec = InnerDecoder::new(chash, 32);
        for f in &frags {
            if dec.is_complete() {
                break;
            }
            dec.push(f);
        }
        assert!(dec.is_complete());
    });
    bench("coeff_row derivation (ref bools, k=32)", scale(1000), 0, || {
        let _ = coeff_row_bools(&chash, rng.next_u64(), 32);
    });
    bench("coeff_row derivation (k=32)", scale(2000), 0, || {
        let _ = coeff_row(&chash, rng.next_u64(), 32);
    });

    // Outer code.
    let object = {
        let mut o = vec![0u8; 4 << 20];
        rng.fill_bytes(&mut o);
        o
    };
    bench("outer encode 4MiB (10,8)", scale(5), object.len(), || {
        let _ = outer::encode_object(&object, b"s", 8, 10);
    });
    let (_, chunks) = outer::encode_object(&object, b"s", 8, 10);
    bench("outer decode 4MiB (ref clones)", scale(3), object.len(), || {
        let mut dec = OuterDecoderRef::new(8);
        for c in &chunks {
            if dec.is_complete() {
                break;
            }
            dec.push(&c.bytes);
        }
        assert!(dec.is_complete());
    });
    bench("outer decode 4MiB", scale(5), object.len(), || {
        let mut dec = OuterDecoder::new(8);
        for c in &chunks {
            if dec.is_complete() {
                break;
            }
            dec.push(&c.bytes);
        }
        assert!(dec.is_complete());
    });

    // Crypto. "before" = generic double-and-add base multiplication;
    // "after" = the Point::mul_base fixed-base table (see the §Perf log).
    use vault::crypto::bigint::U256;
    use vault::crypto::point::Point;
    let k_scalar = U256::from_le_bytes(&{
        let mut b = [0u8; 32];
        rng.fill_bytes(&mut b);
        b[31] &= 0x0f;
        b
    });
    bench("base mult, double-and-add (before)", scale(50), 0, || {
        let _ = Point::base().mul_scalar(&k_scalar);
    });
    bench("base mult, fixed-base table (after)", scale(50), 0, || {
        let _ = Point::mul_base(&k_scalar);
    });
    let sk = SigningKey::from_seed(&[7; 32]);
    bench("ed25519 sign", scale(50), 0, || {
        let _ = sk.sign(b"persistence claim");
    });
    let sig = sk.sign(b"persistence claim");
    bench("ed25519 verify", scale(50), 0, || {
        assert!(vault::crypto::ed25519::verify(&sk.public, b"persistence claim", &sig));
    });
    bench("vrf prove", scale(20), 0, || {
        let _ = vrf::prove(&sk, b"chunk-selection-alpha");
    });
    let (_, proof) = vrf::prove(&sk, b"chunk-selection-alpha");
    bench("vrf verify", scale(20), 0, || {
        assert!(vrf::verify(&sk.public, b"chunk-selection-alpha", &proof).is_some());
    });
    bench("selection prove (eligible path)", scale(20), 0, || {
        let _ = selection::prove_selection(&sk, &chash, 1, 80, 100);
    });

    // End-to-end simnet event throughput.
    use vault::coordinator::{Cluster, ClusterConfig};
    let t = Timer::start();
    let mut cluster = Cluster::start(ClusterConfig::small_test(if smoke { 16 } else { 64 }));
    let data = vec![9u8; 64 << 10];
    let id = cluster.store_blocking(0, &data, b"p", 0).unwrap().value;
    let _ = cluster.query_blocking(1, &id).unwrap();
    let msgs = cluster.net.stats.msgs;
    println!(
        "{:<38} {:>10.3} s wall ({} msgs, {:.0} msg/s)",
        if smoke { "simnet store+query (16 peers)" } else { "simnet store+query (64 peers)" },
        t.elapsed_s(),
        msgs,
        msgs as f64 / t.elapsed_s()
    );
}
