//! Fig. 8: latency under concurrent STORE+QUERY pairs and concurrent
//! repairs, plus the derived per-day capacity claims (§6.2: "more than
//! 400K STORE and 720K QUERY per day ... over 13M daily object repairs").
//!
//! Run: `cargo bench --bench fig8_concurrency [-- --peers 200]`

use vault::coordinator::{Cluster, ClusterConfig};
use vault::proto::AppEvent;
use vault::util::cli::Args;
use vault::util::rng::Rng;
use vault::util::stats::Samples;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let peers = args.get("peers", 200usize);
    let size = args.get("size", 1 << 18); // 256 KiB

    println!("# Fig 8: mean latency vs concurrent STORE/QUERY pairs (ms virtual)");
    println!("{:>12} {:>10} {:>10}", "concurrent", "store", "query");
    let mut per_day = (0.0, 0.0);
    for conc in [1usize, 5, 20, 50] {
        let mut cfg = ClusterConfig::small_test(peers);
        cfg.vault.op_deadline_ms = 300_000;
        cfg.seed = conc as u64;
        let mut cluster = Cluster::start(cfg);
        let mut rng = Rng::new(conc as u64);
        let mut store_lat = Samples::new();
        let mut query_lat = Samples::new();
        // Launch `conc` stores concurrently.
        let mut objects = Vec::new();
        let mut ops = Vec::new();
        for i in 0..conc {
            let mut data = vec![0u8; size];
            rng.fill_bytes(&mut data);
            let client = (i * 13) % peers;
            ops.push(cluster.net.store(client, &data, format!("c{i}").as_bytes(), 0));
            objects.push(data);
        }
        let mut ids = vec![None; conc];
        let deadline = cluster.net.now_ms() + 400_000;
        while ids.iter().any(|x| x.is_none()) && cluster.net.now_ms() < deadline {
            for (_, ev) in cluster.net.run_for(500) {
                if let AppEvent::StoreDone { op, id, latency_ms } = ev {
                    if let Some(p) = ops.iter().position(|&o| o == op) {
                        ids[p] = Some(id);
                        store_lat.push(latency_ms as f64);
                    }
                }
            }
        }
        // Then `conc` queries concurrently.
        let qops: Vec<u64> = ids
            .iter()
            .enumerate()
            .filter_map(|(i, id)| {
                id.as_ref().map(|id| cluster.net.query((i * 17 + 1) % peers, id))
            })
            .collect();
        let mut done = 0;
        let deadline = cluster.net.now_ms() + 400_000;
        while done < qops.len() && cluster.net.now_ms() < deadline {
            for (_, ev) in cluster.net.run_for(500) {
                if let AppEvent::QueryDone { op, latency_ms, .. } = ev {
                    if qops.contains(&op) {
                        query_lat.push(latency_ms as f64);
                        done += 1;
                    }
                }
            }
        }
        println!("{conc:>12} {:>10.0} {:>10.0}", store_lat.mean(), query_lat.mean());
        if conc == 50 {
            // Derived capacity: conc ops per mean-latency window.
            let day_ms = 86_400_000.0;
            per_day = (
                conc as f64 * day_ms / store_lat.mean().max(1.0),
                conc as f64 * day_ms / query_lat.mean().max(1.0),
            );
        }
    }
    println!(
        "# derived capacity at 50 concurrent: {:.0} STOREs/day, {:.0} QUERYs/day",
        per_day.0, per_day.1
    );

    println!("\n# Fig 8 (repairs): mean repair latency vs concurrent repairs");
    println!("{:>12} {:>10}", "concurrent", "repair_ms");
    for conc in [10usize, 50, 150] {
        let mut cfg = ClusterConfig::small_test(peers);
        cfg.vault.heartbeat_ms = 5_000;
        cfg.vault.suspicion_ms = 15_000;
        cfg.vault.tick_ms = 5_000;
        cfg.seed = 100 + conc as u64;
        let mut cluster = Cluster::start(cfg);
        let mut rng = Rng::new(conc as u64);
        // Store ceil(conc / n_outer) objects to get enough chunks.
        let n_outer = cluster.config().vault.n_outer;
        let objs = conc.div_ceil(n_outer);
        let mut chashes = Vec::new();
        for i in 0..objs {
            let mut data = vec![0u8; 1 << 16];
            rng.fill_bytes(&mut data);
            if let Ok(res) = cluster.store_blocking((i * 3) % peers, &data, b"r", 0) {
                chashes.extend(res.value.chunks);
            }
        }
        chashes.truncate(conc);
        let start = cluster.net.now_ms();
        for c in &chashes {
            cluster.evict_one_member(c);
        }
        let mut lat = Samples::new();
        let deadline = start + 900_000;
        let mut seen = std::collections::HashSet::new();
        while seen.len() < chashes.len() && cluster.net.now_ms() < deadline {
            for (_, ev) in cluster.net.run_for(2_000) {
                if let AppEvent::RepairJoined { chash, .. } = ev {
                    if chashes.contains(&chash) && seen.insert(chash) {
                        lat.push((cluster.net.now_ms() - start) as f64);
                    }
                }
            }
        }
        println!("{conc:>12} {:>10.0}   (completed {}/{})", lat.mean(), seen.len(), chashes.len());
        if conc == 150 {
            let day_ms = 86_400_000.0;
            println!(
                "# derived repair capacity: {:.0} repairs/day",
                conc as f64 * day_ms / lat.mean().max(1.0)
            );
        }
    }
}
