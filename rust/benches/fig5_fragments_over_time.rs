//! Fig. 5: honest-node fragment count for one traced chunk over 10
//! simulated years, for two inner-code configurations.
//!
//! Run: `cargo bench --bench fig5_fragments_over_time`

use vault::sim::durability;
use vault::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let nodes = args.get("nodes", 10_000usize);
    let churn = args.get("churn", 2.0f64);

    println!("# Fig 5: fragments on honest alive nodes over 10 years (k=32)");
    let mut traces = Vec::new();
    for (k, r) in [(32usize, 80usize), (32, 48)] {
        let rep = durability::run(&durability::SimConfig {
            n_nodes: nodes,
            n_objects: 1,
            k_inner: k,
            r_inner: r,
            churn_per_year: churn,
            // Lazy average-rate repair (§3.2): rateless codes tolerate
            // bursty symbol loss, so repair may lag failures by days --
            // this is what makes the fragment count *fluctuate* in the
            // paper's figure rather than snap back instantly.
            detect_hours: 96.0,
            duration_years: 10.0,
            trace: true,
            trace_interval_hours: 24.0 * 7.0, // weekly samples
            seed: 7,
            ..Default::default()
        });
        traces.push(((k, r), rep.trace));
    }
    println!("{:>10} {:>12} {:>12} {:>10}", "years", "cfg(32,80)", "cfg(32,48)", "k=32 floor");
    let len = traces[0].1.len().min(traces[1].1.len());
    for i in (0..len).step_by(3) {
        let (t, a) = traces[0].1[i];
        let (_, b) = traces[1].1[i];
        println!("{:>10.2} {a:>12} {b:>12} {:>10}", t / (24.0 * 365.0), 32);
    }
    let min_a = traces[0].1.iter().map(|&(_, c)| c).min().unwrap();
    let min_b = traces[1].1.iter().map(|&(_, c)| c).min().unwrap();
    println!("# minima: (32,80) -> {min_a}, (32,48) -> {min_b}; recoverable while >= 32");
}
