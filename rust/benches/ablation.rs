//! Ablations over DESIGN.md's called-out design choices:
//!
//! 1. heartbeat/suspicion period vs repair convergence and overhead;
//! 2. chunk-cache TTL vs repair traffic (protocol-level, not sim-level);
//! 3. QUERY fan-out vs latency/overhead;
//! 4. MTTDL vs inner-code redundancy (the headline durability metric)
//!    and vs the Byzantine-free ideal.
//!
//! Run: `cargo bench --bench ablation`

use vault::analysis::{ctmc, mttdl};
use vault::coordinator::{Cluster, ClusterConfig};
use vault::proto::AppEvent;
use vault::util::rng::Rng;
use vault::util::stats::Samples;

fn repair_convergence(heartbeat_ms: u64, fanout: usize, cache_ttl: u64, seed: u64) -> (f64, u64, u64) {
    let mut cfg = ClusterConfig::small_test(64);
    cfg.seed = seed;
    cfg.vault.heartbeat_ms = heartbeat_ms;
    cfg.vault.suspicion_ms = heartbeat_ms * 3;
    cfg.vault.tick_ms = heartbeat_ms;
    cfg.vault.fetch_fanout = fanout;
    cfg.vault.cache_ttl_ms = cache_ttl;
    let mut cluster = Cluster::start(cfg);
    let mut rng = Rng::new(seed);
    let mut data = vec![0u8; 64 << 10];
    rng.fill_bytes(&mut data);
    let id = cluster.store_blocking(0, &data, b"abl", 0).expect("store").value;
    let base_msgs = cluster.net.stats.msgs;
    let mut lat = Samples::new();
    for round in 0..4 {
        let chash = id.chunks[round % id.chunks.len()];
        cluster.evict_one_member(&chash);
        let start = cluster.net.now_ms();
        'wait: while cluster.net.now_ms() < start + 20 * heartbeat_ms {
            for (_, ev) in cluster.net.run_for(heartbeat_ms / 2) {
                if let AppEvent::RepairJoined { chash: c, .. } = ev {
                    if c == chash {
                        lat.push((cluster.net.now_ms() - start) as f64);
                        break 'wait;
                    }
                }
            }
        }
    }
    (lat.mean(), cluster.net.stats.msgs - base_msgs, cluster.net.total_repair_traffic())
}

fn main() {
    println!("# Ablation 1: heartbeat period vs repair convergence (4 forced evictions)");
    println!("{:>14} {:>14} {:>12} {:>14}", "heartbeat_ms", "repair_ms", "msgs", "repair_bytes");
    for hb in [2_000u64, 5_000, 15_000, 30_000] {
        let (lat, msgs, traffic) = repair_convergence(hb, 12, 0, 1);
        println!("{hb:>14} {lat:>14.0} {msgs:>12} {traffic:>14}");
    }

    println!("\n# Ablation 2: chunk-cache TTL vs protocol repair traffic");
    println!("{:>14} {:>14} {:>14}", "cache_ttl_ms", "repair_ms", "repair_bytes");
    for ttl in [0u64, 60_000, 3_600_000] {
        let (lat, _, traffic) = repair_convergence(5_000, 12, ttl, 2);
        println!("{ttl:>14} {lat:>14.0} {traffic:>14}");
    }

    println!("\n# Ablation 3: QUERY fan-out vs latency and message cost");
    println!("{:>10} {:>12} {:>12}", "fanout", "query_ms", "msgs");
    for fanout in [9usize, 12, 16, 24] {
        let mut cfg = ClusterConfig::small_test(64);
        cfg.vault.fetch_fanout = fanout;
        cfg.seed = 50 + fanout as u64;
        let mut cluster = Cluster::start(cfg);
        let mut rng = Rng::new(fanout as u64);
        let mut data = vec![0u8; 128 << 10];
        rng.fill_bytes(&mut data);
        let id = cluster.store_blocking(0, &data, b"f", 0).expect("store").value;
        let before = cluster.net.stats.msgs;
        let q = cluster.query_blocking(3, &id).expect("query");
        assert_eq!(q.value, data);
        println!("{fanout:>10} {:>12} {:>12}", q.latency_ms, cluster.net.stats.msgs - before);
    }

    println!("\n# Ablation 4: MTTDL vs inner-code redundancy (chain steps; churn_q=0.02)");
    println!("{:>12} {:>16} {:>16} {:>10}", "code (n,k)", "mttdl", "ideal (f=0)", "ratio");
    for (n, k) in [(48usize, 32usize), (64, 32), (80, 32), (112, 32)] {
        let cfg = ctmc::CtmcConfig { n, k, churn_q: 0.02, ..Default::default() };
        match mttdl::mttdl_vs_ideal(&cfg) {
            Some((real, ideal, ratio)) => println!(
                "{:>12} {real:>16.3e} {ideal:>16.3e} {ratio:>10.3}",
                format!("({n},{k})")
            ),
            None => println!("{:>12} {:>16}", format!("({n},{k})"), "inf"),
        }
    }
}
