//! Fig. 4: repair traffic (object-size units, first year) vs number of
//! objects (left) and churn rate (right); VAULT with chunk-cache TTLs
//! {0, 24, 48}h vs the Ceph-like replicated baseline.
//!
//! Run: `cargo bench --bench fig4_repair_traffic [-- --nodes 100000]`

use vault::sim::{durability, replica};
use vault::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let nodes = args.get("nodes", 20_000usize);
    let seed = args.get("seed", 42u64);

    println!("# Fig 4 (left): repair traffic vs number of objects (churn=2/yr, 1 year)");
    println!("{:>8} {:>12} {:>12} {:>12} {:>12}", "objects", "vault_0h", "vault_24h", "vault_48h", "baseline");
    for objects in [500usize, 1000, 2000, 4000] {
        let mut row = Vec::new();
        for cache in [0.0, 24.0, 48.0] {
            let r = durability::run(&durability::SimConfig {
                n_nodes: nodes,
                n_objects: objects,
                churn_per_year: 2.0,
                cache_ttl_hours: cache,
                duration_years: 1.0,
                seed,
                ..Default::default()
            });
            row.push(r.repair_traffic_objects);
        }
        let b = replica::run(&replica::ReplicaConfig {
            n_nodes: nodes,
            n_objects: objects,
            churn_per_year: 2.0,
            duration_years: 1.0,
            seed,
            ..Default::default()
        });
        println!(
            "{objects:>8} {:>12.1} {:>12.1} {:>12.1} {:>12.1}",
            row[0], row[1], row[2], b.repair_traffic_objects
        );
    }

    println!("\n# Fig 4 (right): repair traffic vs churn rate (1000 objects, 1 year)");
    println!("{:>10} {:>12} {:>12} {:>12} {:>12}", "churn/yr", "vault_0h", "vault_24h", "vault_48h", "baseline");
    for churn in [0.5f64, 1.0, 2.0, 4.0, 8.0] {
        let mut row = Vec::new();
        for cache in [0.0, 24.0, 48.0] {
            let r = durability::run(&durability::SimConfig {
                n_nodes: nodes,
                n_objects: 1000,
                churn_per_year: churn,
                cache_ttl_hours: cache,
                duration_years: 1.0,
                seed,
                ..Default::default()
            });
            row.push(r.repair_traffic_objects);
        }
        let b = replica::run(&replica::ReplicaConfig {
            n_nodes: nodes,
            n_objects: 1000,
            churn_per_year: churn,
            duration_years: 1.0,
            seed,
            ..Default::default()
        });
        println!(
            "{churn:>10.1} {:>12.1} {:>12.1} {:>12.1} {:>12.1}",
            row[0], row[1], row[2], b.repair_traffic_objects
        );
    }
}
