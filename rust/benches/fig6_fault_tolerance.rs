//! Fig. 6: percentage of lost objects under Byzantine participants (top)
//! and targeted attacks (bottom); three VAULT configurations each vs the
//! replicated baseline.
//!
//! Run: `cargo bench --bench fig6_fault_tolerance`

use vault::sim::{attack, durability, replica};
use vault::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let nodes = args.get("nodes", 10_000usize);
    let objects = args.get("objects", 400usize);
    let churn = args.get("churn", 6.0f64);

    println!("# Fig 6 (top): lost objects vs byzantine fraction (1 year, churn {churn}/yr)");
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>12}",
        "byz", "vault(32,48)", "vault(32,80)", "vault(32,112)", "baseline"
    );
    for byz in [0.0f64, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5] {
        let mut row = Vec::new();
        for r_inner in [48usize, 80, 112] {
            let rep = durability::run(&durability::SimConfig {
                n_nodes: nodes,
                n_objects: objects,
                r_inner,
                churn_per_year: churn,
                byzantine_frac: byz,
                duration_years: 1.0,
                seed: 9,
                ..Default::default()
            });
            row.push(rep.lost_object_frac * 100.0);
        }
        let b = replica::run(&replica::ReplicaConfig {
            n_nodes: nodes,
            n_objects: objects,
            churn_per_year: churn,
            byzantine_frac: byz,
            duration_years: 1.0,
            seed: 9,
            ..Default::default()
        });
        println!(
            "{byz:>8.2} {:>11.1}% {:>11.1}% {:>11.1}% {:>11.1}%",
            row[0], row[1], row[2],
            b.lost_object_frac * 100.0
        );
    }

    println!("\n# Fig 6 (bottom): lost objects vs targeted-attack fraction");
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>12}",
        "attacked", "vault(10,8)", "vault(12,8)", "vault(14,8)", "baseline"
    );
    for frac in [0.01f64, 0.02, 0.05, 0.1, 0.15, 0.2, 0.25, 0.3] {
        let mut row = Vec::new();
        for n_outer in [10usize, 12, 14] {
            let loss = attack::vault_attack_loss(&attack::AttackConfig {
                n_nodes: nodes,
                n_objects: objects,
                n_outer,
                attacked_frac: frac,
                trials: 8,
                seed: 11,
                ..Default::default()
            });
            row.push(loss * 100.0);
        }
        let b = attack::baseline_attack_loss(nodes, objects, 256, 3, frac, 11) * 100.0;
        println!(
            "{frac:>8.2} {:>11.1}% {:>11.1}% {:>11.1}% {b:>11.1}%",
            row[0], row[1], row[2]
        );
    }
}
