//! Fig. 10: CPU micro-benchmarks — object encode/decode time across
//! coding parameters (top), and single-fragment repair cost (bottom).
//! Reported for both the native codec and the XLA artifact path (when
//! `artifacts/` is built).
//!
//! Run: `cargo bench --bench fig10_micro [-- --size 16777216]`

use vault::codec::outer::encode_object;
use vault::codec::{InnerDecoder, InnerEncoder, OuterDecoder};
use vault::runtime::{default_artifact_dir, Runtime};
use vault::util::cli::Args;
use vault::util::rng::Rng;
use vault::util::Timer;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    // 16 MiB stands in for the paper's 1 GiB single-host object.
    let size = args.get("size", 16usize << 20);
    let mut rng = Rng::new(1);
    let mut object = vec![0u8; size];
    rng.fill_bytes(&mut object);

    let rt = Runtime::artifacts_available(&default_artifact_dir())
        .then(|| Runtime::load(&default_artifact_dir()).expect("artifacts"));

    println!("# Fig 10 (top): encode/decode one {}-MiB object (ms CPU)", size >> 20);
    println!(
        "{:>14} {:>10} {:>10} {:>12} {:>12}",
        "config", "encode", "decode", "encode-xla", "repair-frag"
    );
    for (k_inner, r_inner, n_outer, k_outer) in
        [(16usize, 40usize, 10usize, 8usize), (32, 80, 10, 8), (64, 160, 10, 8), (32, 80, 14, 8)]
    {
        // Encode: outer + inner fragment generation for all chunks.
        let t = Timer::start();
        let (_, chunks) = encode_object(&object, b"bench", k_outer, n_outer);
        let mut encoders = Vec::new();
        let indices: Vec<u64> = (0..r_inner as u64).collect();
        let mut all_frags = Vec::new();
        for c in &chunks {
            let enc = InnerEncoder::new(c.chash, &c.bytes, k_inner);
            all_frags.push(enc.fragments(&indices));
            encoders.push(enc);
        }
        let encode_ms = t.elapsed_ms();

        // Decode: k_outer chunks from k_inner+eps fragments each.
        let t = Timer::start();
        let mut outer = OuterDecoder::new(k_outer);
        for (ci, c) in chunks.iter().enumerate().take(k_outer + 1) {
            let mut dec = InnerDecoder::new(c.chash, k_inner);
            for f in &all_frags[ci] {
                if dec.is_complete() {
                    break;
                }
                dec.push(f);
            }
            outer.push(&dec.recover().unwrap());
            if outer.is_complete() {
                break;
            }
        }
        assert_eq!(outer.recover().unwrap(), object);
        let decode_ms = t.elapsed_ms();

        // XLA artifact encode of one chunk's worth, scaled to the object.
        let xla_ms = rt
            .as_ref()
            .and_then(|rt| {
                if ![16, 32, 64].contains(&k_inner) {
                    return None;
                }
                let c = &chunks[0];
                let t = Timer::start();
                rt.encode_chunk(&c.chash, &c.bytes, k_inner, &indices).ok()?;
                Some(t.elapsed_ms() * n_outer as f64)
            })
            .map(|ms| format!("{ms:>12.0}"))
            .unwrap_or_else(|| format!("{:>12}", "n/a"));

        // Repair: reconstruct one fragment from k_inner fragments.
        let t = Timer::start();
        let c = &chunks[0];
        let mut dec = InnerDecoder::new(c.chash, k_inner);
        for f in &all_frags[0] {
            if dec.is_complete() {
                break;
            }
            dec.push(f);
        }
        let chunk = dec.recover().unwrap();
        let _new_frag = InnerEncoder::new(c.chash, &chunk, k_inner).fragment(999_999);
        let repair_ms = t.elapsed_ms();

        println!(
            "{:>14} {encode_ms:>10.0} {decode_ms:>10.0} {xla_ms} {repair_ms:>12.1}",
            format!("({n_outer},{k_outer})x({k_inner},{r_inner})")
        );
    }
    println!("# shape check: encode/decode stable across params; repair << decode");
}
