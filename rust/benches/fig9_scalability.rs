//! Fig. 9: STORE/QUERY/repair latency with increasing system size —
//! near-constant latency is the expected shape.
//!
//! Run: `cargo bench --bench fig9_scalability [-- --sweep 100,250,500,1000]`

use vault::coordinator::{Cluster, ClusterConfig};
use vault::proto::AppEvent;
use vault::util::cli::Args;
use vault::util::rng::Rng;
use vault::util::stats::Samples;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let sweep = args.list("sweep", &[100usize, 250, 500, 800]);
    let ops = args.get("ops", 3usize);
    let size = args.get("size", 1 << 18);

    println!("# Fig 9: latency vs number of peers (ms virtual)");
    println!("{:>8} {:>10} {:>10} {:>10}", "peers", "store", "query", "repair");
    for &peers in &sweep {
        let mut cfg = ClusterConfig::small_test(peers);
        cfg.seed = peers as u64;
        cfg.vault.heartbeat_ms = 5_000;
        cfg.vault.suspicion_ms = 15_000;
        cfg.vault.tick_ms = 5_000;
        let mut cluster = Cluster::start(cfg);
        let mut rng = Rng::new(peers as u64);
        let (mut s, mut q, mut r) = (Samples::new(), Samples::new(), Samples::new());
        for _ in 0..ops {
            let mut data = vec![0u8; size];
            rng.fill_bytes(&mut data);
            let c = cluster.random_client();
            let Ok(stored) = cluster.store_blocking(c, &data, b"f9", 0) else { continue };
            s.push(stored.latency_ms as f64);
            let c = cluster.random_client();
            if let Ok(got) = cluster.query_blocking(c, &stored.value) {
                assert_eq!(got.value, data);
                q.push(got.latency_ms as f64);
            }
            let chash = stored.value.chunks[0];
            cluster.evict_one_member(&chash);
            let start = cluster.net.now_ms();
            'rep: while cluster.net.now_ms() < start + 300_000 {
                for (_, ev) in cluster.net.run_for(2_000) {
                    if let AppEvent::RepairJoined { chash: c2, .. } = ev {
                        if c2 == chash {
                            r.push((cluster.net.now_ms() - start) as f64);
                            break 'rep;
                        }
                    }
                }
            }
        }
        println!("{peers:>8} {:>10.0} {:>10.0} {:>10.0}", s.mean(), q.mean(), r.mean());
    }
}
