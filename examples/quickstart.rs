//! Quickstart: bring up a 64-peer world-wide VAULT cluster (virtual
//! time), store an object, read it back from another region, survive a
//! churn event.
//!
//! Run: `cargo run --release --example quickstart`

use vault::coordinator::{Cluster, ClusterConfig};
use vault::util::rng::Rng;

fn main() {
    // A small cluster with down-scaled coding parameters (groups must
    // fit the population): inner (8,20), outer (4,5) ⇒ 3.125x redundancy,
    // the same ratio as the paper's (32,80)x(8,10).
    let mut cluster = Cluster::start(ClusterConfig::small_test(64));

    // 256 KiB of application data.
    let mut rng = Rng::new(2024);
    let mut document = vec![0u8; 256 << 10];
    rng.fill_bytes(&mut document);

    // STORE from a peer in us-west. The returned ObjectId (the chunk
    // hashes) is the *private* handle — only its holder can retrieve.
    let stored = cluster
        .store_blocking(0, &document, b"alice-secret-key", 0)
        .expect("store should complete");
    println!(
        "stored {} KiB as {} chunks in {} ms (virtual)",
        document.len() >> 10,
        stored.value.chunks.len(),
        stored.latency_ms
    );

    // QUERY from a peer in another region.
    let fetched = cluster.query_blocking(3, &stored.value).expect("query should complete");
    assert_eq!(fetched.value, document);
    println!("query from ap-southeast: {} ms, bit-exact", fetched.latency_ms);

    // Churn five peers; the decentralized repair protocol restores every
    // chunk group without any coordinator.
    cluster.churn(5);
    cluster.net.run_for(120_000);
    let fetched = cluster.query_blocking(7, &stored.value).expect("query after churn");
    assert_eq!(fetched.value, document);
    println!("after churning 5 peers: still intact ({} ms)", fetched.latency_ms);
    println!(
        "network totals: {} msgs, {:.1} MiB, repair traffic {:.1} KiB",
        cluster.net.stats.msgs,
        cluster.net.stats.bytes as f64 / (1 << 20) as f64,
        cluster.net.total_repair_traffic() as f64 / 1024.0
    );
}
