//! Open-loop concurrent client traffic through the uniform `VaultApi`
//! submission/completion surface — the same generator drives the serial
//! cluster, the sharded cluster, and the IPFS-like baseline.
//!
//! Run: `cargo run --release --example open_loop`

use vault::api::{OpOutcome, VaultApi};
use vault::baseline::ipfs_like::{IpfsConfig, IpfsNet};
use vault::coordinator::workload::{run_open_loop, OpenLoopSpec};
use vault::coordinator::{Cluster, ClusterConfig};

fn main() {
    let spec = OpenLoopSpec {
        seed: 2024,
        total_ops: 60,
        target_in_flight: 24,
        store_frac: 0.3, // 70/30 get/store mix
        mean_interarrival_ms: 80.0,
        object_size: 24 * 1024,
        ..Default::default()
    };

    // ---- hand-rolled submission/completion, serial runtime ----------
    let mut cluster = Cluster::start(ClusterConfig::small_test(64));
    let doc = vec![7u8; 32 * 1024];
    let seeded = cluster.store_blocking(0, &doc, b"owner", 0).expect("seed store").value;
    // Eight reads of the same object in flight at once; completions
    // surface asynchronously as virtual time is driven forward.
    let handles: Vec<_> = (1..9).map(|c| cluster.submit_get(c, &seeded)).collect();
    println!("submitted {} concurrent reads, {} in flight", handles.len(), cluster.in_flight());
    while cluster.in_flight() > 0 {
        cluster.drive_for(1_000);
    }
    for done in cluster.poll_completions() {
        let ok = matches!(&done.outcome, OpOutcome::Fetched(data) if *data == doc);
        println!(
            "  {:?} finished at t={} ms (latency {} ms, {} B, intact={ok})",
            done.handle,
            done.finished_ms,
            done.latency_ms(),
            done.bytes
        );
    }

    // ---- the same open-loop generator over every backend ------------
    let mut refs = vec![seeded];
    let report = run_open_loop(&mut cluster, &spec, &mut refs);
    println!("serial cluster   : {}", report.summary());

    let mut sharded = Cluster::start_sharded(ClusterConfig::small_test(256), 8);
    let mut refs = Vec::new();
    let report = run_open_loop(&mut sharded, &spec, &mut refs);
    println!("sharded cluster  : {}", report.summary());

    let mut baseline = IpfsNet::new(IpfsConfig { n_peers: 256, ..Default::default() });
    let mut refs = Vec::new();
    let report = run_open_loop(&mut baseline, &spec, &mut refs);
    println!("ipfs-like baseline: {}", report.summary());
}
