//! Retrievability audit plane (ISSUE 7): two nodes quietly withhold the
//! fragments they store while still heartbeating on time — the failure
//! mode the durability plane alone cannot see. Beacon-scheduled audits
//! sample their storage each epoch, the quorum ledger turns repeated
//! non-answers into *suspect* verdicts, and the repair path treats
//! suspects as dead and re-homes their fragments onto honest recruits.
//!
//! Prints the detection epoch, the eviction, and the post-repair
//! availability of the withheld chunk.
//!
//! Run: `cargo run --release --example audit_detection`

use vault::api::VaultApi;
use vault::coordinator::{Cluster, ClusterConfig};
use vault::crypto::Hash256;
use vault::dht::NodeId;
use vault::net::simnet::SimNet;
use vault::util::rng::Rng;

const EPOCH_MS: u64 = 60_000;
/// A withholder counts as evicted once this many distinct honest
/// auditors have independently marked it suspect (the same bound the
/// bench uses).
const NEED_SUSPECTERS: usize = 3;

/// Honest live peers currently willing and able to serve `chash`.
fn serving_holders(cluster: &Cluster<SimNet>, chash: &Hash256) -> usize {
    (0..cluster.net.len())
        .filter(|&i| cluster.net.is_up(i))
        .filter(|&i| cluster.net.peer(i).serves_fragment(chash))
        .count()
}

/// How many live honest peers have marked `wid` suspect in their audit
/// ledger.
fn suspecters_of(cluster: &Cluster<SimNet>, wid: &NodeId) -> usize {
    (0..cluster.net.len())
        .filter(|&i| cluster.net.is_up(i))
        .filter(|&i| !cluster.net.peer(i).fault.refuse_frags)
        .filter(|&i| cluster.net.peer(i).id() != *wid)
        .filter(|&i| cluster.net.peer(i).is_audit_suspect(wid))
        .count()
}

fn main() {
    // 32 peers, 60 s epochs, audits sampling half the group per epoch.
    let mut cfg = ClusterConfig::small_test(32);
    cfg.epoch_ms = EPOCH_MS;
    cfg.vault.rotation_grace_ms = 20_000;
    cfg.vault.heartbeat_ms = 5_000;
    cfg.vault.suspicion_ms = 15_000;
    cfg.vault.tick_ms = 5_000;
    cfg.vault.audits = true;
    cfg.vault.audit_rate = 0.5;
    let mut cluster = Cluster::start(cfg);
    println!(
        "cluster up: {} peers, audits on (rate 0.5, quorum {}, {} fail-epochs to suspect)",
        cluster.net.len(),
        cluster.net.peer(0).cfg.audit_quorum,
        cluster.net.peer(0).cfg.audit_fail_epochs,
    );

    // Seed two objects through real STORE sagas.
    let mut rng = Rng::new(17);
    let mut ids = Vec::new();
    for o in 0..2 {
        let mut data = vec![0u8; 12_000];
        rng.fill_bytes(&mut data);
        let client = cluster.random_client();
        let stored = cluster
            .store_blocking(client, &data, format!("audit-demo-{o}").as_bytes(), 0)
            .expect("store");
        ids.push((stored.value, data));
    }
    let chash = ids[0].0.chunks[0];
    let healthy = serving_holders(&cluster, &chash);
    println!("stored {} objects; watched chunk has {healthy} serving holders", ids.len());

    // Two holders of the watched chunk go quiet: they keep heartbeating
    // (so failure detection sees nothing) but refuse every fragment
    // request. Durability accounting still counts their copies.
    let mut withheld: Vec<NodeId> = Vec::new();
    for i in 0..cluster.net.len() {
        if withheld.len() >= 2 {
            break;
        }
        if cluster.net.is_up(i) && cluster.net.peer(i).fragment_index(&chash).is_some() {
            cluster.net.peer_mut(i).fault.refuse_frags = true;
            withheld.push(cluster.net.peer(i).id());
        }
    }
    println!(
        "{} nodes now withhold their fragments while heartbeating normally\n",
        withheld.len()
    );

    // Cross epoch boundaries until every withholder is suspected by a
    // quorum of distinct honest auditors.
    let mut detection_epoch = None;
    for e in 1..=6u64 {
        let boundary = ((cluster.net.now_ms() / EPOCH_MS) + 1) * EPOCH_MS;
        cluster.drive(boundary + 5_000);
        let counts: Vec<usize> = withheld.iter().map(|w| suspecters_of(&cluster, w)).collect();
        println!(
            "epoch {e}: suspecters per withholder {counts:?}, serving holders {}",
            serving_holders(&cluster, &chash)
        );
        if counts.iter().all(|&c| c >= NEED_SUSPECTERS) {
            detection_epoch = Some(e);
            break;
        }
    }
    let detected = detection_epoch.expect("withholders must be detected within the budget");
    println!("\ndetected: both withholders suspect after {detected} epoch boundaries");

    // Suspects are excluded from the alive set, so the repair plane sees
    // a fragment deficit and recruits honest replacements. Give it two
    // more epochs to settle.
    let before_joined: u64 =
        (0..cluster.net.len()).map(|i| cluster.net.peer(i).metrics.repairs_joined).sum();
    cluster.drive(cluster.net.now_ms() + 2 * EPOCH_MS);
    let joined: u64 = (0..cluster.net.len())
        .map(|i| cluster.net.peer(i).metrics.repairs_joined)
        .sum::<u64>()
        - before_joined;
    let serving = serving_holders(&cluster, &chash);
    println!(
        "eviction + repair: {joined} fragments re-homed onto honest recruits, \
         watched chunk back to {serving} serving holders"
    );

    // No honest node was ever swept up by the audits.
    for i in 0..cluster.net.len() {
        if !cluster.net.is_up(i) {
            continue;
        }
        for s in cluster.net.peer(i).audit_suspects() {
            assert!(withheld.contains(&s), "audit plane must never suspect an honest node");
        }
    }
    println!("zero honest nodes suspected across every live ledger");

    // Availability restored: every object reads back bit-exact even with
    // the withholders still refusing.
    for (id, want) in &ids {
        let client = cluster.random_client();
        let got = cluster.query_blocking(client, id).expect("query");
        assert_eq!(&got.value, want);
    }
    println!("all objects read back bit-exact with withholders evicted");
}
