//! Epoch-anchored verifiable placement (ISSUE 5): drive a small cluster
//! across two chain boundaries and watch the ledger + rotation at work —
//! per-epoch on-chain bytes (churn-bound, never per-object), the beacon
//! chain verifying end-to-end, and the migration the rotation causes
//! (fragments re-homed by the repair path while retiring members serve
//! through their grace window).
//!
//! Run: `cargo run --release --example epoch_rotation`

use vault::api::VaultApi;
use vault::coordinator::{Cluster, ClusterConfig};
use vault::util::rng::Rng;

fn migrated_fragments(cluster: &Cluster) -> u64 {
    (0..cluster.net.len()).map(|i| cluster.net.peer(i).metrics.repairs_joined).sum()
}

fn main() {
    // 48 peers on the simulated chain: 30 s epochs, 10 s rotation grace.
    let mut cfg = ClusterConfig::small_test(48);
    cfg.epoch_ms = 30_000;
    cfg.vault.rotation_grace_ms = 10_000;
    cfg.vault.heartbeat_ms = 5_000;
    cfg.vault.suspicion_ms = 15_000;
    cfg.vault.tick_ms = 5_000;
    let mut cluster = Cluster::start(cfg);
    println!(
        "chain up: epoch {}, {} bonded identities",
        cluster.epoch_view().unwrap().epoch,
        cluster.epoch_view().unwrap().n_nodes()
    );

    // Seed three objects through real STORE sagas — placement is
    // sampled from the epoch beacon, nothing lands on the chain.
    let mut rng = Rng::new(5);
    let mut ids = Vec::new();
    for o in 0..3 {
        let mut data = vec![0u8; 12_000];
        rng.fill_bytes(&mut data);
        let client = cluster.random_client();
        let stored = cluster
            .store_blocking(client, &data, format!("epoch-demo-{o}").as_bytes(), 0)
            .expect("store");
        ids.push((stored.value, data));
    }
    println!("stored {} objects ({} chunk groups)", ids.len(), ids.len() * 5);

    // Cross two epoch boundaries; churn two identities per epoch so the
    // ledger has bond/unbond traffic to seal.
    for round in 0..2 {
        let before_frags = migrated_fragments(&cluster);
        let epoch_before = cluster.ledger().unwrap().current_epoch();
        cluster.churn(2);
        let boundary = ((cluster.net.now_ms() / 30_000) + 1) * 30_000;
        cluster.drive(boundary + 25_000); // boundary + rotation settle
        let ledger = cluster.ledger().unwrap();
        let sealed = epoch_before + 1;
        println!(
            "round {round}: sealed epoch {sealed} | on-chain bytes this epoch: {} \
             ({} txs) | fragments migrated by rotation: {}",
            ledger.onchain_bytes_of(sealed),
            ledger.view(sealed).map(|v| v.tx_count).unwrap_or(0),
            migrated_fragments(&cluster) - before_frags,
        );
    }

    // Any node can re-derive the whole beacon chain from public data.
    let ledger = cluster.ledger().unwrap();
    assert_eq!(ledger.verify_chain(), None);
    println!(
        "beacon chain verifies from genesis through epoch {} ({} total on-chain bytes)",
        ledger.current_epoch(),
        ledger.total_onchain_bytes()
    );

    // Rotation preserved every object.
    for (id, want) in &ids {
        let client = cluster.random_client();
        let got = cluster.query_blocking(client, id).expect("query");
        assert_eq!(&got.value, want);
    }
    println!("all objects read back bit-exact after two rotations");
}
