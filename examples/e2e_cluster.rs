//! End-to-end driver (DESIGN.md): the full system on a realistic small
//! workload, proving all layers compose.
//!
//! 200 peers across the paper's five regions; a mixed-size corpus of 30
//! objects is stored, the cluster then lives through Poisson churn,
//! 10% Byzantine conversion and a targeted attack while decentralized
//! repair runs; finally every object is read back bit-exact and the run
//! reports latency/throughput/repair statistics (recorded in
//! EXPERIMENTS.md §E2E).
//!
//! Run: `cargo run --release --example e2e_cluster [-- --peers 200 --objects 30]`

use vault::coordinator::{workload::Corpus, Cluster, ClusterConfig};
use vault::proto::AppEvent;
use vault::util::cli::Args;
use vault::util::stats::Samples;
use vault::util::Timer;

fn main() {
    let args = Args::from_env();
    let peers = args.get("peers", 200usize);
    let n_objects = args.get("objects", 30usize);
    let wall = Timer::start();

    let mut cfg = ClusterConfig::small_test(peers);
    cfg.vault.heartbeat_ms = 10_000;
    cfg.vault.suspicion_ms = 30_000;
    cfg.vault.tick_ms = 10_000;
    cfg.vault.cache_ttl_ms = 48 * 3_600 * 1_000;
    cfg.vault.op_deadline_ms = 120_000;
    let r_target = cfg.vault.r_inner;
    println!(
        "== e2e: {peers} peers / 5 regions, inner ({},{}), outer ({},{}), 48h chunk cache ==",
        cfg.vault.k_inner, cfg.vault.r_inner, cfg.vault.k_outer, cfg.vault.n_outer
    );
    let mut cluster = Cluster::start(cfg);

    // Phase 1: ingest a mixed-size corpus (4 KiB – 1 MiB).
    let corpus = Corpus::generate_mixed(77, n_objects, 4 << 10, 1 << 20);
    let mut store_lat = Samples::new();
    let mut handles = Vec::new();
    let ingest_start = cluster.net.now_ms();
    for (i, (data, secret)) in corpus.objects.iter().enumerate() {
        let client = cluster.random_client();
        let res = cluster.store_blocking(client, data, secret, 0).expect("store");
        store_lat.push(res.latency_ms as f64);
        handles.push((res.value, data.clone()));
        if i % 10 == 9 {
            println!("  ingested {}/{n_objects}", i + 1);
        }
    }
    let ingest_virtual_s = (cluster.net.now_ms() - ingest_start) as f64 / 1e3;
    println!(
        "phase 1 STORE: {} objects, latency {} (virtual ms), {:.1} obj/s virtual",
        n_objects,
        store_lat.summary(),
        n_objects as f64 / ingest_virtual_s.max(0.001)
    );

    // Phase 2: adversity — churn 10% of peers, convert 10% to Byzantine,
    // blackhole 5%; let repair work for 10 virtual minutes.
    println!("phase 2: churn {}, byzantine {}, attack {} peers", peers / 10, peers / 10, peers / 20);
    cluster.churn(peers / 10);
    for i in 0..peers / 10 {
        let idx = (i * 13 + 1) % cluster.net.len();
        cluster.net.peer_mut(idx).cfg.byzantine = true;
    }
    cluster.attack_random(peers / 20);
    let mut repairs = 0usize;
    for _ in 0..60 {
        for (_, ev) in cluster.net.run_for(10_000) {
            if matches!(ev, AppEvent::RepairJoined { .. }) {
                repairs += 1;
            }
        }
    }
    let healthy = handles
        .iter()
        .flat_map(|(id, _)| id.chunks.iter())
        .filter(|c| cluster.net.surviving_fragments(c) >= r_target)
        .count();
    let total_chunks: usize = handles.iter().map(|(id, _)| id.chunks.len()).sum();
    println!(
        "phase 2 done: {repairs} repair joins, {healthy}/{total_chunks} groups back at R, \
         repair traffic {:.2} MiB",
        cluster.net.total_repair_traffic() as f64 / (1 << 20) as f64
    );

    // Phase 3: read everything back, bit-exact.
    let mut query_lat = Samples::new();
    let mut intact = 0usize;
    for (id, want) in &handles {
        let client = cluster.random_client();
        match cluster.query_blocking(client, id) {
            Ok(res) => {
                assert_eq!(&res.value, want, "silent corruption!");
                intact += 1;
                query_lat.push(res.latency_ms as f64);
            }
            Err(e) => println!("  QUERY FAILED: {e}"),
        }
    }
    println!(
        "phase 3 QUERY: {intact}/{} objects intact, latency {} (virtual ms)",
        handles.len(),
        query_lat.summary()
    );
    println!(
        "== e2e complete: {:.1}s wall, {:.1} min virtual, {} msgs, {:.1} MiB on the wire ==",
        wall.elapsed_s(),
        cluster.net.now_ms() as f64 / 60_000.0,
        cluster.net.stats.msgs,
        cluster.net.stats.bytes as f64 / (1 << 20) as f64
    );
    assert_eq!(intact, handles.len(), "durability violated");
}
