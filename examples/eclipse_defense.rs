//! Eclipse defense (ISSUE 8): an attacker floods a victim's routing
//! table with sybil contacts minted from one hosting cluster, so the
//! victim's lookups converge onto attacker-controlled peers and honest
//! fragment holders become unreachable — storage is intact, routing is
//! not. The DHT bucket-diversity guard (per-bucket region cap plus
//! verified-contact preference) bounds how much of any bucket the
//! attacker can occupy, whatever the flood volume.
//!
//! Runs the identical poisoning flood twice — guard off, guard on — and
//! prints the victim's table composition and the measured availability
//! floor (fraction of lookups that still reach an honest peer).
//!
//! Run: `cargo run --release --example eclipse_defense`

use vault::dht::kademlia::{eclipse_trial, EclipseReport};

const HONEST: usize = 100;
const SYBILS: usize = 300;
const FLOOD_ROUNDS: usize = 3;
const LOOKUPS: usize = 40;
const SEED: u64 = 8;

fn describe(label: &str, r: &EclipseReport) {
    println!(
        "  {label:<9} table: {:>3} honest / {:>3} sybil resident | \
         lookups reaching an honest peer: {:>2}/{} ({:>5.1}%)",
        r.honest_resident,
        r.sybils_resident,
        r.honest_reach,
        r.lookups,
        100.0 * r.reach_frac()
    );
}

fn main() {
    println!(
        "eclipse attack: {SYBILS} sybils from one region flood a victim that knows \
         {HONEST} honest peers, {FLOOD_ROUNDS} rounds, then {LOOKUPS} lookups\n"
    );

    let off = eclipse_trial(HONEST, SYBILS, FLOOD_ROUNDS, LOOKUPS, SEED, false);
    let on = eclipse_trial(HONEST, SYBILS, FLOOD_ROUNDS, LOOKUPS, SEED, true);
    println!("guard off — sybils evict honest contacts freely:");
    describe("unguarded", &off);
    println!("guard on  — region cap + verified-contact preference per bucket:");
    describe("guarded", &on);

    let floor = on.reach_frac();
    println!(
        "\nmeasured availability floor with the guard: {:.1}% of lookups still \
         reach an honest peer (unguarded: {:.1}%)",
        100.0 * floor,
        100.0 * off.reach_frac()
    );
    assert!(
        on.reach_frac() > off.reach_frac(),
        "the guard must strictly improve honest reach"
    );
    assert!(floor >= 0.9, "guarded reach {floor:.3} fell below the 90% floor");
    assert!(
        on.honest_resident > off.honest_resident,
        "the guard must retain more honest contacts"
    );
    println!("the same flood, the same seed — only the bucket admission policy differs");
}
