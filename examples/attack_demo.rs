//! Adversary demo: a cluster where a third of the peers are Byzantine
//! (they ack stores and heartbeat, but store nothing), plus a targeted
//! attack that blackholes live peers — VAULT keeps the data readable;
//! the same adversary destroys the replicated baseline (Fig. 6 story).
//!
//! Run: `cargo run --release --example attack_demo`

use vault::coordinator::{Cluster, ClusterConfig};
use vault::proto::ClaimVerify;
use vault::sim::{durability, replica};
use vault::util::rng::Rng;

fn main() {
    // --- live cluster under 33% Byzantine peers -----------------------
    let mut cfg = ClusterConfig::small_test(90);
    cfg.byzantine_frac = 0.33;
    cfg.vault.claim_verify = ClaimVerify::Always; // full proof checking
    cfg.vault.fetch_fanout = 24;
    cfg.vault.op_deadline_ms = 120_000;
    let mut cluster = Cluster::start(cfg);

    let mut rng = Rng::new(5);
    let mut data = vec![0u8; 128 << 10];
    rng.fill_bytes(&mut data);
    let client = cluster.random_client();
    let id = cluster.store_blocking(client, &data, b"owner", 0).expect("store").value;
    let client = cluster.random_client();
    let got = cluster.query_blocking(client, &id).expect("query");
    assert_eq!(got.value, data);
    println!("[byzantine-33%] store+query survived; query {} ms", got.latency_ms);

    // Escalate: targeted attack on 10% of the remaining peers.
    cluster.attack_random(9);
    let client = cluster.random_client();
    let got = cluster.query_blocking(client, &id).expect("query under attack");
    assert_eq!(got.value, data);
    println!("[+targeted-10%] still readable; query {} ms", got.latency_ms);

    // --- year-scale simulation comparison (Fig. 6 top) ----------------
    println!("\n1-year simulated loss rates (10K nodes, churn 6/yr):");
    for byz in [0.1f64, 0.2, 0.33] {
        let v = durability::run(&durability::SimConfig {
            n_nodes: 10_000,
            n_objects: 300,
            churn_per_year: 6.0,
            byzantine_frac: byz,
            duration_years: 1.0,
            ..Default::default()
        });
        let b = replica::run(&replica::ReplicaConfig {
            n_nodes: 10_000,
            n_objects: 300,
            churn_per_year: 6.0,
            byzantine_frac: byz,
            duration_years: 1.0,
            ..Default::default()
        });
        println!(
            "  byz {byz:.0}%: vault {:.1}% lost | 3-replica baseline {:.1}% lost",
            v.lost_object_frac * 100.0,
            b.lost_object_frac * 100.0
        );
    }
}
