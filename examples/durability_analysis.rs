//! Analytical durability walkthrough (Appendix A): build the CTMC for a
//! chunk group, evaluate the loss series natively and — when artifacts
//! are built — through the AOT XLA graph, then print the closed-form
//! bounds that justify the paper's parameter choices.
//!
//! Run: `cargo run --release --example durability_analysis`

use vault::analysis::{bounds, ctmc};
use vault::runtime::{default_artifact_dir, Runtime};

fn main() {
    println!("== Lemma 4.1: chunk-group CTMC ==");
    for (label, q) in [("calm (0.2% churn/step)", 0.002), ("stressed (2%/step)", 0.02)] {
        let chain = ctmc::build_chain(&ctmc::CtmcConfig {
            n: 80,
            k: 32,
            churn_q: q,
            ..Default::default()
        });
        let series = chain.absorb_series(512);
        println!("{label}:");
        for t in [24usize, 168, 512] {
            println!("  P(group lost by T={t:>3}) = {:.3e}", series[t - 1]);
        }
        println!(
            "  P(object lost, 10 chunks)  = {:.3e}",
            chain.object_loss_bound(512, 10)
        );
    }

    if Runtime::artifacts_available(&default_artifact_dir()) {
        let rt = Runtime::load(&default_artifact_dir()).expect("artifacts");
        let chain = ctmc::build_chain(&ctmc::CtmcConfig {
            n: 60,
            k: 32,
            churn_q: 0.01,
            ..Default::default()
        });
        let native = chain.absorb_series(512);
        let (theta, init, absorb) = chain.padded(64);
        let art = rt.ctmc_series(&theta, &init, absorb, 512).expect("ctmc artifact");
        let max_err =
            native.iter().zip(&art).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        println!("\nAOT XLA graph agrees with native to |err| <= {max_err:.2e}");
    }

    println!("\n== Eq. (3)/(4): can a fresh group start too Byzantine? (F = N/3) ==");
    for (n, k) in [(80u64, 32u64), (48, 32), (160, 64)] {
        println!(
            "  (n={n:>3}, k={k:>2}): exact {:.3e}, hoeffding {:.3e}",
            bounds::initial_invalid_prob(100_000, 33_333, n, k),
            bounds::initial_invalid_hoeffding(n, k)
        );
    }

    println!("\n== Lemma 4.2: targeted attacks vs the opaque outer code ==");
    for (omega, phi) in [(10_000u64, 1_000u64), (100_000, 1_000), (100_000, 10_000)] {
        println!(
            "  {omega:>6} objects, {phi:>5} groups attackable: P(success) <= {:.3e}",
            bounds::targeted_attack_bound(omega, 8, 2, phi, 8)
        );
    }
    println!("\nnegligible threshold used by the paper: 2^-128 = {:.3e}", bounds::NEGLIGIBLE);
}
