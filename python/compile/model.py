"""L2 JAX compute graphs for VAULT's inner rateless code.

Two graphs, both AOT-lowered by ``aot.py`` and executed from the rust
runtime (``rust/src/runtime``) on the PJRT CPU client:

* ``rlf_encode`` — batch fragment generation (STORE / repair hot path);
  thin wrapper over the L1 Pallas kernel so both lower into one HLO.
* ``rlf_decode`` — GF(2) Gauss-Jordan elimination recovering the k source
  blocks from k fragments (QUERY / repair path).  Branchless masked
  elimination inside a fixed k-step ``fori_loop``; pivot permutation is
  applied with a gather at the end.

Shapes are static per artifact; the rust runtime tiles arbitrary chunk
sizes into fixed-width word panels and loops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.xorgemm import xor_gemm


def rlf_encode(coeff: jax.Array, blocks: jax.Array) -> jax.Array:
    """Encode ``r`` fragments from ``k`` blocks.  See ``xor_gemm``."""
    return xor_gemm(coeff, blocks)


def rlf_decode(coeff_bits: jax.Array, payload: jax.Array):
    """Solve the GF(2) system ``C @ X = F`` for the source blocks ``X``.

    Args:
      coeff_bits: uint32[k, kw] bit-packed coefficient rows (row i is the
        coefficient vector of fragment i; bit c of row i set means block c
        participates in fragment i).
      payload: uint32[k, w] fragment payload words.

    Returns:
      (blocks uint32[k, w], ok uint32) — ``ok`` is 1 when the system was
      full rank and ``blocks`` holds the decoded source blocks, else 0.
    """
    k, kw = coeff_bits.shape
    _, w = payload.shape
    rows = jnp.arange(k, dtype=jnp.uint32)

    def step(col, state):
        c, f, used, perm, ok = state
        word = col // 32
        bit = jnp.uint32(col % 32)
        colbits = (c[:, word] >> bit) & jnp.uint32(1)  # (k,)
        elig = jnp.where(used == 0, colbits, jnp.uint32(0))
        p = jnp.argmax(elig)  # first eligible pivot row
        ok = ok & (elig[p] > 0).astype(jnp.uint32)
        used = used.at[p].set(jnp.uint32(1))
        perm = perm.at[col].set(p.astype(jnp.uint32))
        # Eliminate the pivot row from every other row that has this bit.
        elim = colbits * (rows != p.astype(jnp.uint32)).astype(jnp.uint32)
        c = c ^ elim[:, None] * c[p]
        f = f ^ elim[:, None] * f[p]
        return c, f, used, perm, ok

    init = (
        coeff_bits.astype(jnp.uint32),
        payload.astype(jnp.uint32),
        jnp.zeros((k,), jnp.uint32),
        jnp.zeros((k,), jnp.uint32),
        jnp.uint32(1),
    )
    _, f, _, perm, ok = jax.lax.fori_loop(0, k, step, init)
    return f[perm], ok
