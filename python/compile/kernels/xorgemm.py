"""L1 Pallas kernel: GF(2) XOR-GEMM — VAULT's inner-code encode hot loop.

Encoding ``r`` fragments from ``k`` source blocks of ``w`` uint32 words is
a matrix product in the (AND, XOR) semiring:

    out[r, w] = XOR_i ( C[r, i] ? B[i, w] : 0 )

TPU mapping (see DESIGN.md §Hardware-Adaptation): the kernel is tiled the
way an MXU matmul would be — a grid over (R-tiles, W-tiles, K-tiles) with
the K axis innermost so each output tile accumulates (XOR) while K-panels
of the source blocks stream HBM→VMEM.  On real TPU hardware this runs on
the VPU (integer XOR); under the CPU PJRT plugin we lower with
``interpret=True`` which expands to plain HLO.

VMEM footprint per grid step (defaults bR=64, bK=32, bW=256, 4-byte
words): (bR*bK + bK*bW + bR*bW) * 4 B = 112 KiB — far below the ~16 MiB
VMEM budget, leaving room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xor_gemm_kernel(c_ref, b_ref, o_ref):
    """One (bR, bW) output tile; accumulates one K-panel per grid step."""
    c = c_ref[...].astype(jnp.uint32)  # (bR, bK) 0/1 coefficients
    b = b_ref[...].astype(jnp.uint32)  # (bK, bW) packed words
    masked = c[:, :, None] * b[None, :, :]  # (bR, bK, bW)
    acc = jax.lax.reduce(masked, jnp.uint32(0), jax.lax.bitwise_xor, [1])

    # K is the innermost grid axis: zero the tile on the first panel, then
    # XOR-accumulate the remaining panels into the same output block.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] ^= acc


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("block_r", "block_k", "block_w"))
def xor_gemm(
    coeff: jax.Array,
    blocks: jax.Array,
    *,
    block_r: int = 64,
    block_k: int = 32,
    block_w: int = 256,
) -> jax.Array:
    """GF(2) mat-mul via the Pallas kernel.

    Args:
      coeff:  uint32[r, k], entries in {0, 1}.
      blocks: uint32[k, w].

    Returns:
      uint32[r, w].
    """
    r, k = coeff.shape
    k2, w = blocks.shape
    assert k == k2, f"coeff k={k} != blocks k={k2}"

    br = min(block_r, _ceil_to(r, 8))
    bk = min(block_k, _ceil_to(k, 8))
    bw = min(block_w, _ceil_to(w, 8))
    rp, kp, wp = _ceil_to(r, br), _ceil_to(k, bk), _ceil_to(w, bw)

    # Zero-pad to tile multiples: XOR with zero is identity, and 0-coeff
    # rows/cols contribute nothing, so padding never changes the result.
    cpad = jnp.zeros((rp, kp), jnp.uint32).at[:r, :k].set(coeff)
    bpad = jnp.zeros((kp, wp), jnp.uint32).at[:k, :w].set(blocks)

    grid = (rp // br, wp // bw, kp // bk)
    out = pl.pallas_call(
        _xor_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bw), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((br, bw), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, wp), jnp.uint32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(cpad, bpad)
    return out[:r, :w]
