"""Pure-jnp correctness oracles for the VAULT coding kernels.

The inner rateless code of VAULT is a random linear fountain over GF(2):
fragment ``r`` is the XOR of the source blocks selected by coefficient row
``C[r, :]``.  Treating blocks as vectors of uint32 words, encoding is a
matrix product in the (AND, XOR) semiring:

    out[r, w] = XOR_i ( C[r, i] ? B[i, w] : 0 )

These oracles are deliberately simple (no tiling, no pallas) and are the
ground truth pytest pins the L1 kernel and the rust native codec against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def xor_gemm_ref(coeff: jax.Array, blocks: jax.Array) -> jax.Array:
    """GF(2) mat-mul reference.

    Args:
      coeff:  uint32[r, k] with entries in {0, 1}.
      blocks: uint32[k, w] packed words.

    Returns:
      uint32[r, w] fragments.
    """
    coeff = coeff.astype(jnp.uint32)
    blocks = blocks.astype(jnp.uint32)
    # Select (multiply by 0/1) then XOR-reduce over the k axis.  The mask
    # multiply is exact for 0/1 coefficients in uint32.
    masked = coeff[:, :, None] * blocks[None, :, :]
    return jax.lax.reduce(masked, jnp.uint32(0), jax.lax.bitwise_xor, [1])


def gf2_decode_ref(coeff_bits, payload):
    """Reference GF(2) Gauss-Jordan solve, plain numpy (host only).

    Args:
      coeff_bits: uint32[k, kw] bit-packed coefficient rows (kw*32 >= k).
      payload:    uint32[k, w] fragment payloads.

    Returns:
      (blocks uint32[k, w], ok bool) — ``ok`` False when the coefficient
      matrix is singular.
    """
    import numpy as np

    C = np.array(coeff_bits, dtype=np.uint64)
    F = np.array(payload, dtype=np.uint64)
    k = C.shape[0]
    used = np.zeros(k, dtype=bool)
    perm = np.zeros(k, dtype=np.int64)
    for col in range(k):
        word, bit = divmod(col, 32)
        colbits = (C[:, word] >> np.uint64(bit)) & np.uint64(1)
        elig = np.where(~used, colbits, 0)
        p = int(np.argmax(elig))
        if elig[p] == 0:
            return np.zeros_like(F, dtype=np.uint32), False
        used[p] = True
        perm[col] = p
        mask = colbits == 1
        mask[p] = False
        C[mask] ^= C[p]
        F[mask] ^= F[p]
    return F[perm].astype(np.uint32), True
