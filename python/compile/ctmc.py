"""L2 JAX graph for the Appendix-A CTMC durability model (Lemma 4.1).

The durability of one chunk group is a Markov chain over the number of
Byzantine members b in {0..n-k} plus one absorbing "lost" state.  Given
the (s x s) stochastic matrix Theta (built natively by
``rust/src/analysis/ctmc.rs`` from churn rate, eviction rate and group
parameters) and the hypergeometric initial vector I, the probability the
group is lost by step T is the absorbing component of I @ Theta^T.

The graph scans T = 1..t mat-vec steps and emits the whole series — the
quantity inside Eq. (1) of the paper.  Matrices are padded to a fixed
size ``s`` so one artifact serves every (n, k) configuration with
n-k+2 <= s; padding rows/cols are identity and never mix (the native
builder guarantees pad states are self-absorbing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ctmc_absorb_series(theta: jax.Array, init: jax.Array, absorb_idx: jax.Array):
    """Absorbing-probability series for T = 1..t.

    Args:
      theta: f64[s, s] row-stochastic transition matrix.
      init:  f64[s] initial distribution.
      absorb_idx: s-length one-hot f64 selector of the absorbing state.

    Returns:
      f64[t] where entry T-1 = (init @ theta^T) . absorb_idx.
    """

    def step(v, _):
        v = v @ theta
        return v, v @ absorb_idx

    t = _SCAN_STEPS
    _, series = jax.lax.scan(step, init, None, length=t)
    return series


# Fixed trip count baked into the artifact; the rust side chains multiple
# executions (warm-starting from the final vector) for longer horizons.
_SCAN_STEPS = 512


def ctmc_absorb_series_with_final(theta, init, absorb_idx):
    """Like ``ctmc_absorb_series`` but also returns the final state vector
    so the caller can chain windows of ``_SCAN_STEPS`` steps."""

    def step(v, _):
        v = v @ theta
        return v, v @ absorb_idx

    final, series = jax.lax.scan(step, init, None, length=_SCAN_STEPS)
    return series, final
