"""L1 kernel roofline / VMEM analysis (DESIGN.md §Hardware-Adaptation).

Under ``interpret=True`` the Pallas kernel's wall time is CPU-numpy, not
a TPU proxy, so the perf pass optimizes *structure*: this tool computes,
for a sweep of (bR, bK, bW) block shapes, the per-grid-step VMEM
footprint, the HBM traffic per output byte (arithmetic-intensity dual),
and the resulting roofline bound on a nominal TPU memory system — the
quantities that decide whether a tile schedule is sound before any
hardware run.

Usage:  cd python && python -m compile.roofline [r] [k] [w]
"""

from __future__ import annotations

import sys

WORD = 4  # uint32
VMEM_BUDGET = 16 << 20  # ~16 MiB per TPU core
HBM_GBPS = 1200.0  # nominal v4-ish HBM bandwidth
VPU_GOPS = 4000.0  # nominal vector-unit 32-bit ops/s (GOP/s)


def analyze(r: int, k: int, w: int, br: int, bk: int, bw: int) -> dict:
    """Static cost model for one block shape on the (r,k,w) problem."""
    br, bk, bw = min(br, r), min(bk, k), min(bw, w)
    grid = ((r + br - 1) // br, (w + bw - 1) // bw, (k + bk - 1) // bk)
    steps = grid[0] * grid[1] * grid[2]
    vmem = (br * bk + bk * bw + br * bw) * WORD
    # HBM traffic: every grid step streams its C and B tiles; the output
    # tile is resident across the K axis (innermost) and written once.
    bytes_in = steps * (br * bk + bk * bw) * WORD
    bytes_out = grid[0] * grid[1] * br * bw * WORD
    total_bytes = bytes_in + bytes_out
    # Work: one AND+XOR per (r,k,w) cell.
    ops = 2 * r * k * w
    intensity = ops / total_bytes  # ops per HBM byte
    # Roofline: min(compute bound, bandwidth bound), seconds.
    t_bw = total_bytes / (HBM_GBPS * 1e9)
    t_compute = ops / (VPU_GOPS * 1e9)
    return {
        "block": (br, bk, bw),
        "grid": grid,
        "steps": steps,
        "vmem": vmem,
        "vmem_ok": vmem * 2 <= VMEM_BUDGET,  # x2 for double buffering
        "hbm_bytes": total_bytes,
        "intensity": intensity,
        "bound": "bandwidth" if t_bw > t_compute else "compute",
        "t_roofline_us": max(t_bw, t_compute) * 1e6,
    }


def sweep(r: int, k: int, w: int):
    shapes = [
        (8, 8, 128),
        (32, 32, 128),
        (64, 32, 256),  # shipped default
        (64, 64, 256),
        (128, 32, 512),
        (r, k, 1024),
    ]
    rows = [analyze(r, k, w, *s) for s in shapes]
    return rows


def main() -> None:
    args = [int(a) for a in sys.argv[1:4]] or []
    r, k, w = (args + [80, 32, 4096])[:3]
    print(f"# XOR-GEMM roofline sweep for r={r}, k={k}, w={w} (uint32 words)")
    print(
        f"{'block(bR,bK,bW)':>18} {'grid':>12} {'VMEM/step':>10} {'2xbuf?':>7} "
        f"{'HBM MiB':>9} {'ops/B':>7} {'bound':>10} {'t_roof':>9}"
    )
    for row in sweep(r, k, w):
        print(
            f"{str(row['block']):>18} {str(row['grid']):>12} "
            f"{row['vmem'] / 1024:>8.0f}KB {str(row['vmem_ok']):>7} "
            f"{row['hbm_bytes'] / (1 << 20):>9.2f} {row['intensity']:>7.2f} "
            f"{row['bound']:>10} {row['t_roofline_us']:>7.1f}us"
        )
    best = min(
        (r for r in sweep(r, k, w) if r["vmem_ok"]), key=lambda r: r["t_roofline_us"]
    )
    print(f"# best feasible shape: {best['block']} ({best['bound']}-bound, "
          f"{best['t_roofline_us']:.1f} us roofline)")


if __name__ == "__main__":
    main()
