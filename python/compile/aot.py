"""AOT lowering: JAX graphs -> HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what
the published ``xla`` 0.1.6 crate links) rejects (``proto.id() <=
INT_MAX``).  The text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per graph variant plus ``manifest.json``
describing shapes so the rust runtime can discover and validate them.
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import ctmc, model  # noqa: E402

# (k, r) inner-code configurations used by the evaluation:
#   (32, 80)  — paper default (K_inner=32, R=80)
#   (16, 40)  — "small" config in Fig 5/6/7 sweeps
#   (64, 160) — "conservative" config
# w is the word-panel width the rust runtime tiles chunks into.
ENCODE_VARIANTS = [
    (32, 80, 1024),
    (16, 40, 1024),
    (64, 160, 1024),
    (32, 80, 64),  # small panel used by tests
]
DECODE_VARIANTS = [
    (32, 1024),
    (16, 1024),
    (64, 1024),
    (32, 64),
]
CTMC_STATES = 64  # padded s; serves any (n, k) with n-k+2 <= 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts():
    """Yield (name, hlo_text, manifest_entry) for every artifact."""
    for k, r, w in ENCODE_VARIANTS:
        name = f"rlf_encode_k{k}_r{r}_w{w}"
        lowered = jax.jit(model.rlf_encode).lower(
            _spec((r, k), jnp.uint32), _spec((k, w), jnp.uint32)
        )
        yield name, to_hlo_text(lowered), {
            "kind": "encode",
            "k": k,
            "r": r,
            "w": w,
            "inputs": [["u32", [r, k]], ["u32", [k, w]]],
            "outputs": [["u32", [r, w]]],
        }

    for k, w in DECODE_VARIANTS:
        kw = (k + 31) // 32
        name = f"rlf_decode_k{k}_w{w}"
        lowered = jax.jit(model.rlf_decode).lower(
            _spec((k, kw), jnp.uint32), _spec((k, w), jnp.uint32)
        )
        yield name, to_hlo_text(lowered), {
            "kind": "decode",
            "k": k,
            "kw": kw,
            "w": w,
            "inputs": [["u32", [k, kw]], ["u32", [k, w]]],
            "outputs": [["u32", [k, w]], ["u32", []]],
        }

    s, t = CTMC_STATES, ctmc._SCAN_STEPS
    name = f"ctmc_absorb_s{s}_t{t}"
    lowered = jax.jit(ctmc.ctmc_absorb_series_with_final).lower(
        _spec((s, s), jnp.float64), _spec((s,), jnp.float64), _spec((s,), jnp.float64)
    )
    yield name, to_hlo_text(lowered), {
        "kind": "ctmc",
        "s": s,
        "t": t,
        "inputs": [["f64", [s, s]], ["f64", [s]], ["f64", [s]]],
        "outputs": [["f64", [t]], ["f64", [s]]],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {}
    for name, text, entry in build_artifacts():
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry["file"] = f"{name}.hlo.txt"
        manifest[name] = entry
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)

    # Tab-separated manifest for the (serde-less) rust runtime:
    # name  kind  k  r  w  file   — ctmc packs (s, 0, t).
    with open(os.path.join(args.out, "manifest.tsv"), "w") as f:
        for name, entry in sorted(manifest.items()):
            if entry["kind"] == "encode":
                k, r, w = entry["k"], entry["r"], entry["w"]
            elif entry["kind"] == "decode":
                k, r, w = entry["k"], 0, entry["w"]
            else:  # ctmc
                k, r, w = entry["s"], 0, entry["t"]
            f.write(f"{name}\t{entry['kind']}\t{k}\t{r}\t{w}\t{entry['file']}\n")
    print(f"wrote manifests ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
