"""CTMC absorbing-series graph vs dense numpy oracle."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.ctmc import _SCAN_STEPS, ctmc_absorb_series_with_final


def random_stochastic(rng, s, absorbing):
    m = rng.random((s, s))
    m[absorbing, :] = 0.0
    m[absorbing, absorbing] = 1.0
    m /= m.sum(axis=1, keepdims=True)
    return m


def numpy_series(theta, init, idx, t):
    v = init.copy()
    out = np.zeros(t)
    for i in range(t):
        v = v @ theta
        out[i] = v @ idx
    return out, v


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.sampled_from([4, 16, 64]))
def test_series_matches_numpy(seed, s):
    rng = np.random.default_rng(seed)
    absorbing = s - 1
    theta = random_stochastic(rng, s, absorbing)
    init = rng.random(s)
    init /= init.sum()
    idx = np.zeros(s)
    idx[absorbing] = 1.0
    want, want_final = numpy_series(theta, init, idx, _SCAN_STEPS)
    got, got_final = ctmc_absorb_series_with_final(
        jnp.asarray(theta), jnp.asarray(init), jnp.asarray(idx)
    )
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(np.asarray(got_final), want_final, rtol=1e-10, atol=1e-12)


def test_series_monotone_for_absorbing_chain():
    # Probability mass in an absorbing state never decreases.
    rng = np.random.default_rng(0)
    s = 8
    theta = random_stochastic(rng, s, s - 1)
    init = np.zeros(s)
    init[0] = 1.0
    idx = np.zeros(s)
    idx[-1] = 1.0
    got, _ = ctmc_absorb_series_with_final(
        jnp.asarray(theta), jnp.asarray(init), jnp.asarray(idx)
    )
    g = np.asarray(got)
    assert (np.diff(g) >= -1e-15).all()
    assert g[-1] <= 1.0 + 1e-12


def test_chaining_windows_is_consistent():
    # Running two chained windows == one longer numpy run.
    rng = np.random.default_rng(3)
    s = 6
    theta = random_stochastic(rng, s, s - 1)
    init = np.zeros(s)
    init[0] = 1.0
    idx = np.zeros(s)
    idx[-1] = 1.0
    _, f1 = ctmc_absorb_series_with_final(jnp.asarray(theta), jnp.asarray(init), jnp.asarray(idx))
    s2, _ = ctmc_absorb_series_with_final(jnp.asarray(theta), f1, jnp.asarray(idx))
    want, _ = numpy_series(theta, init, idx, 2 * _SCAN_STEPS)
    np.testing.assert_allclose(np.asarray(s2), want[_SCAN_STEPS:], rtol=1e-9, atol=1e-12)
