"""Minimal deterministic stand-in for `hypothesis` (offline environment).

Implements just the surface these tests use — ``@given`` with keyword
strategies, ``@settings(max_examples=..., deadline=...)``,
``st.integers`` and ``st.sampled_from`` — drawing examples from a fixed
seed so runs are reproducible. When the real hypothesis package is
installed, conftest.py leaves it alone and this module is unused.
"""

import random
from types import SimpleNamespace


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(lo, hi):
    return _Strategy(lambda rng: rng.randint(lo, hi))


def _sampled_from(seq):
    items = list(seq)
    return _Strategy(lambda rng: rng.choice(items))


strategies = SimpleNamespace(integers=_integers, sampled_from=_sampled_from)


def given(**strategy_kw):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", 20)
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strategy_kw.items()}
                fn(*args, **{**kwargs, **drawn})

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco


def settings(max_examples=20, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco
