"""Make the `compile` package importable no matter where pytest runs
from, and fall back to a deterministic local stub when `hypothesis`
is not installed (fully offline environments)."""

import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.dirname(_HERE))

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, _HERE)
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
