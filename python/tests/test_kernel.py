"""L1 Pallas kernel vs pure-jnp oracle: hypothesis sweeps shapes/seeds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import xor_gemm_ref
from compile.kernels.xorgemm import xor_gemm


def rand_case(seed: int, r: int, k: int, w: int):
    rng = np.random.default_rng(seed)
    coeff = rng.integers(0, 2, size=(r, k), dtype=np.uint32)
    blocks = rng.integers(0, 2**32, size=(k, w), dtype=np.uint32)
    return jnp.asarray(coeff), jnp.asarray(blocks)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    r=st.integers(1, 96),
    k=st.integers(1, 48),
    w=st.integers(1, 80),
)
def test_xor_gemm_matches_ref_random_shapes(seed, r, k, w):
    coeff, blocks = rand_case(seed, r, k, w)
    got = xor_gemm(coeff, blocks, block_r=16, block_k=16, block_w=32)
    want = xor_gemm_ref(coeff, blocks)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("r,k,w", [(80, 32, 64), (40, 16, 128), (160, 64, 32), (1, 32, 256)])
def test_xor_gemm_paper_configs(r, k, w):
    coeff, blocks = rand_case(7, r, k, w)
    got = xor_gemm(coeff, blocks)
    want = xor_gemm_ref(coeff, blocks)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("br,bk,bw", [(8, 8, 8), (64, 32, 256), (16, 48, 64)])
def test_xor_gemm_block_shapes_are_equivalent(br, bk, bw):
    coeff, blocks = rand_case(13, 48, 24, 100)
    want = xor_gemm_ref(coeff, blocks)
    got = xor_gemm(coeff, blocks, block_r=br, block_k=bk, block_w=bw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_xor_gemm_zero_coeff_is_zero():
    coeff = jnp.zeros((8, 8), jnp.uint32)
    blocks = jnp.ones((8, 16), jnp.uint32) * jnp.uint32(0xDEADBEEF)
    out = xor_gemm(coeff, blocks)
    assert not np.asarray(out).any()


def test_xor_gemm_identity_coeff_is_passthrough():
    k = 16
    coeff = jnp.eye(k, dtype=jnp.uint32)
    _, blocks = rand_case(3, k, k, 32)
    out = xor_gemm(coeff, blocks)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(blocks))


def test_xor_gemm_linearity():
    # (C1 ^ C2 rows disjoint) encode == encode(C1) ^ encode(C2)
    c1, blocks = rand_case(5, 24, 16, 40)
    c2, _ = rand_case(6, 24, 16, 40)
    both = jnp.asarray(np.asarray(c1) ^ np.asarray(c2))
    lhs = xor_gemm(both, blocks)
    rhs = np.asarray(xor_gemm(c1, blocks)) ^ np.asarray(xor_gemm(c2, blocks))
    np.testing.assert_array_equal(np.asarray(lhs), rhs)
