"""AOT smoke: every artifact lowers to parseable HLO text with entry shapes."""

import json

import pytest

from compile import aot


@pytest.fixture(scope="module")
def artifacts():
    # Lowering all variants once per test session.
    return list(aot.build_artifacts())


def test_all_variants_lower(artifacts):
    names = [n for n, _, _ in artifacts]
    assert len(names) == len(set(names))
    assert len(names) == len(aot.ENCODE_VARIANTS) + len(aot.DECODE_VARIANTS) + 1


def test_hlo_text_looks_like_hlo(artifacts):
    for name, text, _ in artifacts:
        assert "HloModule" in text, name
        assert "ENTRY" in text, name


def test_manifest_entries_consistent(artifacts):
    for name, _, entry in artifacts:
        assert entry["kind"] in ("encode", "decode", "ctmc")
        for dt, shape in entry["inputs"]:
            assert dt in ("u32", "f64")
            assert all(isinstance(d, int) for d in shape)
        json.dumps(entry)  # serializable


def test_encode_entry_shapes_in_text(artifacts):
    # The HLO entry computation should mention the u32 parameter shapes.
    for name, text, entry in artifacts:
        if entry["kind"] != "encode":
            continue
        r, k, w = entry["r"], entry["k"], entry["w"]
        assert f"u32[{r},{k}]" in text, name
        assert f"u32[{k},{w}]" in text, name
