"""L2 decode graph: Gauss-Jordan over GF(2) vs numpy oracle + identities."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import gf2_decode_ref, xor_gemm_ref
from compile.model import rlf_decode


def pack_bits(rows: np.ndarray) -> np.ndarray:
    """uint32[k,k] 0/1 -> bit-packed uint32[k, ceil(k/32)]."""
    k = rows.shape[1]
    kw = (k + 31) // 32
    out = np.zeros((rows.shape[0], kw), dtype=np.uint32)
    for c in range(k):
        out[:, c // 32] |= (rows[:, c].astype(np.uint32) & 1) << (c % 32)
    return out


def full_rank_coeff(rng, k):
    """Random full-rank GF(2) k x k matrix (rejection sampling)."""
    while True:
        m = rng.integers(0, 2, size=(k, k), dtype=np.uint32)
        _, ok = gf2_decode_ref(pack_bits(m), np.zeros((k, 1), np.uint32))
        if ok:
            return m


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([4, 8, 16, 32]), w=st.integers(1, 40))
def test_decode_recovers_encode(seed, k, w):
    rng = np.random.default_rng(seed)
    blocks = rng.integers(0, 2**32, size=(k, w), dtype=np.uint32)
    coeff = full_rank_coeff(rng, k)
    frags = np.asarray(xor_gemm_ref(jnp.asarray(coeff), jnp.asarray(blocks)))
    got, ok = rlf_decode(jnp.asarray(pack_bits(coeff)), jnp.asarray(frags))
    assert int(ok) == 1
    np.testing.assert_array_equal(np.asarray(got), blocks)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([8, 16]), w=st.integers(1, 16))
def test_decode_matches_numpy_oracle(seed, k, w):
    rng = np.random.default_rng(seed)
    coeff = rng.integers(0, 2, size=(k, k), dtype=np.uint32)
    payload = rng.integers(0, 2**32, size=(k, w), dtype=np.uint32)
    cb = pack_bits(coeff)
    want, want_ok = gf2_decode_ref(cb, payload)
    got, got_ok = rlf_decode(jnp.asarray(cb), jnp.asarray(payload))
    assert int(got_ok) == int(want_ok)
    if want_ok:
        np.testing.assert_array_equal(np.asarray(got), want)


def test_decode_singular_flags_zero():
    k = 8
    coeff = np.zeros((k, k), np.uint32)  # rank 0
    payload = np.ones((k, 4), np.uint32)
    _, ok = rlf_decode(jnp.asarray(pack_bits(coeff)), jnp.asarray(payload))
    assert int(ok) == 0


def test_decode_duplicate_rows_singular():
    rng = np.random.default_rng(0)
    k = 16
    coeff = full_rank_coeff(rng, k)
    coeff[3] = coeff[7]  # duplicate row -> singular
    payload = rng.integers(0, 2**32, size=(k, 8), dtype=np.uint32)
    _, ok = rlf_decode(jnp.asarray(pack_bits(coeff)), jnp.asarray(payload))
    assert int(ok) == 0


def test_decode_identity_matrix_passthrough():
    k = 32
    rng = np.random.default_rng(1)
    payload = rng.integers(0, 2**32, size=(k, 8), dtype=np.uint32)
    cb = pack_bits(np.eye(k, dtype=np.uint32))
    got, ok = rlf_decode(jnp.asarray(cb), jnp.asarray(payload))
    assert int(ok) == 1
    np.testing.assert_array_equal(np.asarray(got), payload)
