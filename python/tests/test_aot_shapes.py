"""Shape/semantics checks for the exact AOT variants the rust runtime
loads: the encode graph must equal the oracle at every (k, r, w) shipped
in the manifest, and panel-tiling (how rust feeds wide chunks through
fixed-width artifacts) must be equivalent to one wide call."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels.ref import xor_gemm_ref
from compile.model import rlf_encode


def rand(seed, r, k, w):
    rng = np.random.default_rng(seed)
    coeff = rng.integers(0, 2, size=(r, k), dtype=np.uint32)
    blocks = rng.integers(0, 2**32, size=(k, w), dtype=np.uint32)
    return coeff, blocks


@pytest.mark.parametrize("k,r,w", aot.ENCODE_VARIANTS)
def test_every_shipped_encode_variant_matches_oracle(k, r, w):
    # Use a reduced word count for the very wide variants to keep the
    # interpret-mode run fast; the artifact shape itself is exercised by
    # the rust integration tests.
    w_eff = min(w, 128)
    coeff, blocks = rand(k * r, r, k, w_eff)
    got = rlf_encode(jnp.asarray(coeff), jnp.asarray(blocks))
    want = xor_gemm_ref(jnp.asarray(coeff), jnp.asarray(blocks))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_panel_tiling_equivalence():
    # rust runtime splits a wide chunk into fixed-w panels and loops the
    # artifact; XOR-GEMM must commute with column partitioning.
    k, r, w, panel = 16, 24, 96, 32
    coeff, blocks = rand(3, r, k, w)
    whole = np.asarray(rlf_encode(jnp.asarray(coeff), jnp.asarray(blocks)))
    parts = [
        np.asarray(rlf_encode(jnp.asarray(coeff), jnp.asarray(blocks[:, i : i + panel])))
        for i in range(0, w, panel)
    ]
    np.testing.assert_array_equal(whole, np.concatenate(parts, axis=1))


def test_row_batching_equivalence():
    # rust batches fragment indices into r-row calls with zero padding;
    # zero coefficient rows must produce zero fragments and not disturb
    # the real rows.
    k, r, w = 16, 24, 64
    coeff, blocks = rand(4, r, k, w)
    coeff[r // 2 :, :] = 0  # padded tail
    out = np.asarray(rlf_encode(jnp.asarray(coeff), jnp.asarray(blocks)))
    assert not out[r // 2 :, :].any()
    want = np.asarray(
        xor_gemm_ref(jnp.asarray(coeff[: r // 2]), jnp.asarray(blocks))
    )
    np.testing.assert_array_equal(out[: r // 2], want)


def test_manifest_tsv_format():
    # The rust runtime parses name\tkind\tk\tr\tw\tfile.
    rows = []
    for name, _, entry in aot.build_artifacts():
        if entry["kind"] == "encode":
            rows.append((name, entry["k"], entry["r"], entry["w"]))
    assert len(rows) == len(aot.ENCODE_VARIANTS)
    names = [r[0] for r in rows]
    assert all("\t" not in n for n in names)
