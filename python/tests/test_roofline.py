"""Static cost-model sanity for the L1 block-shape sweep."""

from compile.roofline import analyze, sweep


def test_default_shape_fits_vmem_with_double_buffering():
    row = analyze(80, 32, 4096, 64, 32, 256)
    assert row["vmem_ok"]
    assert row["vmem"] == (64 * 32 + 32 * 256 + 64 * 256) * 4  # 112 KiB


def test_bound_flips_with_tile_size():
    # Tiny output tiles re-stream B constantly -> bandwidth-bound;
    # the shipped 64x32x256 tile amortizes enough to cross the ridge.
    tiny = analyze(80, 32, 4096, 8, 8, 128)
    shipped = analyze(80, 32, 4096, 64, 32, 256)
    assert tiny["bound"] == "bandwidth"
    assert shipped["bound"] == "compute"
    assert shipped["intensity"] > tiny["intensity"]


def test_bigger_r_tiles_reduce_hbm_traffic():
    # B-panel re-reads scale with r/bR: doubling the output-row tile
    # halves the dominant traffic term.
    small = analyze(128, 32, 4096, 16, 32, 256)
    large = analyze(128, 32, 4096, 64, 32, 256)
    assert large["hbm_bytes"] < small["hbm_bytes"]


def test_sweep_contains_a_feasible_shape():
    rows = sweep(80, 32, 4096)
    assert any(r["vmem_ok"] for r in rows)
    for r in rows:
        assert r["steps"] >= 1
        assert r["t_roofline_us"] > 0
